//! Virtual-time fleet engine: many paced streams against the shared
//! device pool, on the DES kernel from [`crate::sim`].
//!
//! This is the multi-stream generalisation of
//! [`crate::coordinator::engine::run_online`]: each stream gets its own
//! paced arrivals, freshness window and synchronizer; the pool's
//! work-conserving dispatcher keeps every idle device busy with the
//! fairest backlogged stream. The engine deals only in frame *timing*
//! (fates carry empty detection lists) — detection quality under
//! multi-stream contention is the wall-clock path's job
//! ([`crate::fleet::serve`]), which runs real detectors per frame.
//!
//! Scenarios can script mid-run control events (attach/detach of streams
//! and devices), which is what makes elasticity experiments — autoscaling
//! a pool under changing load — expressible in milliseconds of wall time.

use crate::coordinator::sync::Fate;
use crate::device::DeviceInstance;
use crate::fleet::admission::AdmissionPolicy;
use crate::fleet::metrics::{finish_stream, FleetReport, StreamAccum};
use crate::fleet::pool::Job;
use crate::fleet::registry::{ControlAction, ControlEvent, FleetRegistry};
use crate::fleet::stream::{StreamId, StreamSpec};
use crate::sim::EventQueue;
use crate::types::FrameId;
use crate::util::Rng;

/// One fleet run's full description.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Devices attached from t = 0.
    pub devices: Vec<DeviceInstance>,
    /// Streams attached at t = 0 (admission runs in order).
    pub streams: Vec<StreamSpec>,
    /// Scripted mid-run attach/detach events.
    pub events: Vec<ControlEvent>,
    pub admission: AdmissionPolicy,
    pub seed: u64,
}

impl Scenario {
    pub fn new(devices: Vec<DeviceInstance>, streams: Vec<StreamSpec>) -> Scenario {
        Scenario {
            devices,
            streams,
            events: Vec::new(),
            admission: AdmissionPolicy::default(),
            seed: 0,
        }
    }

    pub fn with_admission(mut self, admission: AdmissionPolicy) -> Scenario {
        self.admission = admission;
        self
    }

    pub fn with_events(mut self, events: Vec<ControlEvent>) -> Scenario {
        self.events = events;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Scenario {
        self.seed = seed;
        self
    }
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    /// Frame `fid` of stream `sid` arrives.
    Arrival { sid: StreamId, fid: FrameId },
    /// The device's in-flight job finishes.
    ServiceDone { dev: usize },
    /// Apply `scenario.events[idx]`.
    Control { idx: usize },
}

fn schedule_arrivals(queue: &mut EventQueue<Ev>, reg: &FleetRegistry, sid: StreamId) {
    let s = &reg.streams[sid];
    for fid in 0..s.spec.num_frames {
        queue.schedule(s.capture_ts(fid), Ev::Arrival { sid, fid });
    }
}

fn arrival(reg: &mut FleetRegistry, sid: StreamId, fid: FrameId, now: f64) {
    let s = &mut reg.streams[sid];
    if s.detached {
        return;
    }
    s.arrived += 1;
    if !s.decision.is_admitted() {
        // Rejected stream: every frame is dropped on arrival, so the
        // record log still covers the whole stream.
        s.resolve(fid, Fate::Dropped, now);
        return;
    }
    if !s.keeps(fid) {
        // Degraded stream: admission-mandated subsampling.
        s.resolve(fid, Fate::Dropped, now);
        return;
    }
    if let Some(evicted) = s.window.arrive(fid).evicted {
        s.resolve(evicted, Fate::Dropped, now);
    }
}

/// Work-conserving dispatch: pair idle devices with backlogged streams
/// until one side runs out.
fn dispatch(reg: &mut FleetRegistry, queue: &mut EventQueue<Ev>, rng: &mut Rng) {
    loop {
        let Some(dev) = reg.pool.next_idle() else { break };
        let Some(sid) = reg.pick_stream() else { break };
        let fid = reg.streams[sid]
            .window
            .pull()
            .expect("backlogged stream has a frame");
        let weight = reg.streams[sid].spec.weight.max(1e-9);
        reg.streams[sid].vtime += 1.0 / weight;
        let t = reg.pool.start(dev, Job { stream: sid, fid }, rng);
        queue.schedule_in(t, Ev::ServiceDone { dev });
    }
}

/// Run the scenario to completion and report.
pub fn run_fleet(scenario: &Scenario) -> FleetReport {
    let mut reg = FleetRegistry::new(scenario.devices.clone(), scenario.admission.clone());
    let mut queue: EventQueue<Ev> = EventQueue::new();
    let mut rng = Rng::new(scenario.seed ^ 0x0F1E_E75E_ED00_0001);

    for spec in &scenario.streams {
        let sid = reg.attach_stream(spec.clone(), 0.0);
        schedule_arrivals(&mut queue, &reg, sid);
    }
    for (idx, ev) in scenario.events.iter().enumerate() {
        queue.schedule(ev.at.max(0.0), Ev::Control { idx });
    }

    dispatch(&mut reg, &mut queue, &mut rng);

    while let Some((now, ev)) = queue.pop() {
        match ev {
            Ev::Arrival { sid, fid } => {
                arrival(&mut reg, sid, fid, now);
                dispatch(&mut reg, &mut queue, &mut rng);
            }
            Ev::ServiceDone { dev } => {
                let (job, service) = reg.pool.complete(dev);
                {
                    let s = &mut reg.streams[job.stream];
                    if dev < s.device_busy.len() {
                        s.device_busy[dev] += service;
                        s.device_frames[dev] += 1;
                    }
                    s.resolve(
                        job.fid,
                        Fate::Processed {
                            detections: Vec::new(),
                            device: dev,
                        },
                        now,
                    );
                }
                dispatch(&mut reg, &mut queue, &mut rng);
            }
            Ev::Control { idx } => {
                match scenario.events[idx].action.clone() {
                    ControlAction::AttachStream(spec) => {
                        let sid = reg.attach_stream(spec, now);
                        schedule_arrivals(&mut queue, &reg, sid);
                    }
                    ControlAction::DetachStream(id) => {
                        let drained = reg.detach_stream(id);
                        for fid in drained {
                            reg.streams[id].resolve(fid, Fate::Dropped, now);
                        }
                    }
                    ControlAction::AttachDevice(instance) => {
                        reg.attach_device(instance);
                    }
                    ControlAction::DetachDevice(dev) => {
                        reg.detach_device(dev);
                    }
                }
                dispatch(&mut reg, &mut queue, &mut rng);
            }
        }
    }

    // Frames still windowed when the event queue drains could never be
    // scheduled: a dropped tail, resolved at the end of virtual time.
    let t_end = queue.now();
    for s in reg.streams.iter_mut() {
        let leftover = s.window.drain_remaining();
        for fid in leftover {
            s.resolve(fid, Fate::Dropped, t_end);
        }
    }

    let kinds = reg.pool.kinds();
    let device_labels = reg.pool.labels();
    let device_busy: Vec<f64> = reg.pool.devices().iter().map(|d| d.busy_seconds).collect();
    let device_frames: Vec<u64> = reg.pool.devices().iter().map(|d| d.frames_done).collect();
    let makespan = t_end.max(
        reg.streams
            .iter()
            .map(|s| s.last_resolution)
            .fold(0.0, f64::max),
    );

    let streams = reg
        .streams
        .into_iter()
        .map(|s| {
            let makespan_s = (s.last_resolution - s.attached_at).max(s.spec.duration());
            debug_assert_eq!(
                s.sync.emitted().len() as u64,
                s.arrived,
                "stream {}: record log must cover exactly the arrived frames",
                s.id
            );
            let acc = StreamAccum {
                id: s.id,
                name: s.spec.name.clone(),
                weight: s.spec.weight,
                decision: s.decision,
                records: s.sync.emitted().to_vec(),
                max_reorder_depth: s.sync.max_pending(),
                latency: s.latency,
                device_busy: s.device_busy,
                device_frames: s.device_frames,
                makespan: makespan_s,
                stream_duration: s.spec.duration(),
            };
            finish_stream(acc, &kinds)
        })
        .collect();

    FleetReport {
        streams,
        makespan,
        device_busy,
        device_frames,
        device_labels,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{DetectorModelId, DeviceKind};
    use crate::fleet::admission::Decision;

    fn devices(rates: &[f64]) -> Vec<DeviceInstance> {
        rates
            .iter()
            .enumerate()
            .map(|(i, &r)| {
                DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, i, r)
            })
            .collect()
    }

    fn specs(n: usize, fps: f64, frames: u64, window: usize) -> Vec<StreamSpec> {
        (0..n)
            .map(|i| StreamSpec::new(&format!("s{i}"), fps, frames).with_window(window))
            .collect()
    }

    #[test]
    fn every_arrived_frame_gets_exactly_one_record_in_order() {
        let scenario = Scenario::new(devices(&[2.5, 2.5]), specs(3, 10.0, 80, 4))
            .with_admission(AdmissionPolicy::admit_all())
            .with_seed(7);
        let report = run_fleet(&scenario);
        assert_eq!(report.streams.len(), 3);
        for s in &report.streams {
            assert_eq!(s.records.len(), 80, "stream {}", s.id);
            for (i, r) in s.records.iter().enumerate() {
                assert_eq!(r.frame_id, i as u64);
            }
            assert_eq!(
                s.metrics.frames_processed + s.metrics.frames_dropped,
                s.metrics.frames_total
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let scenario = Scenario::new(devices(&[2.5, 13.5]), specs(4, 8.0, 60, 4)).with_seed(42);
        let a = run_fleet(&scenario);
        let b = run_fleet(&scenario);
        assert_eq!(a.total_processed(), b.total_processed());
        for (sa, sb) in a.streams.iter().zip(&b.streams) {
            assert_eq!(sa.metrics.frames_processed, sb.metrics.frames_processed);
        }
    }

    #[test]
    fn single_stream_single_device_matches_known_drop_shape() {
        // λ=10 vs μ=2.5: the stream keeps ≈ μ/λ of its frames.
        let scenario = Scenario::new(devices(&[2.5]), specs(1, 10.0, 200, 1))
            .with_admission(AdmissionPolicy::admit_all())
            .with_seed(3);
        let report = run_fleet(&scenario);
        let s = &report.streams[0];
        let sigma = s.metrics.processing_fps();
        assert!((sigma - 2.5).abs() < 0.4, "σ {sigma}");
        assert!(s.metrics.drop_rate() > 0.6, "{}", s.metrics.drop_rate());
    }

    #[test]
    fn rejected_stream_gets_all_dropped_records() {
        // Capacity 2.375 with min_rate 1.0: two 5-FPS streams exhaust it;
        // the third is rejected but still fully recorded.
        let scenario = Scenario::new(devices(&[2.5]), specs(3, 5.0, 50, 4)).with_seed(5);
        let report = run_fleet(&scenario);
        let rejected: Vec<_> = report
            .streams
            .iter()
            .filter(|s| s.decision == Decision::Reject)
            .collect();
        assert!(!rejected.is_empty(), "expected at least one rejection");
        for s in &rejected {
            assert_eq!(s.records.len(), 50);
            assert!(s.records.iter().all(|r| r.was_dropped()));
            assert_eq!(s.metrics.frames_processed, 0);
        }
    }

    #[test]
    fn degraded_stream_processes_roughly_its_share() {
        // One device μ=2.5, one stream λ=5: degrade stride ≈ 3
        // (share 2.375); the stream keeps every 3rd frame and processes
        // nearly all kept frames.
        let scenario = Scenario::new(devices(&[2.5]), specs(1, 5.0, 150, 4)).with_seed(11);
        let report = run_fleet(&scenario);
        let s = &report.streams[0];
        match s.decision {
            Decision::Degrade { stride, .. } => assert_eq!(stride, 3),
            ref other => panic!("expected degrade, got {other:?}"),
        }
        let kept = (0..150u64).filter(|f| f % 3 == 0).count() as u64;
        assert!(
            s.metrics.frames_processed >= kept - 3,
            "processed {} of {kept} kept",
            s.metrics.frames_processed
        );
    }

    #[test]
    fn mid_run_device_attach_raises_throughput() {
        // One device for the first 15s, a second from t=15: processed
        // count lands between the always-1 and always-2 device runs.
        let base = Scenario::new(devices(&[2.5]), specs(1, 10.0, 300, 8))
            .with_admission(AdmissionPolicy::admit_all())
            .with_seed(9);
        let one = run_fleet(&base);

        let two_late = base.clone().with_events(vec![ControlEvent {
            at: 15.0,
            action: ControlAction::AttachDevice(DeviceInstance::with_rate(
                DeviceKind::Ncs2,
                DetectorModelId::Yolov3,
                1,
                2.5,
            )),
        }]);
        let elastic = run_fleet(&two_late);

        let both = Scenario::new(devices(&[2.5, 2.5]), specs(1, 10.0, 300, 8))
            .with_admission(AdmissionPolicy::admit_all())
            .with_seed(9);
        let two = run_fleet(&both);

        let (p1, pe, p2) = (
            one.total_processed(),
            elastic.total_processed(),
            two.total_processed(),
        );
        assert!(pe > p1 + 10, "elastic {pe} vs static-1 {p1}");
        assert!(pe < p2, "elastic {pe} vs static-2 {p2}");
    }

    #[test]
    fn mid_run_stream_detach_frees_capacity() {
        // Two streams share one device; stream 0 detaches at t=10, after
        // which stream 1 should process roughly twice as fast.
        let scenario = Scenario::new(devices(&[2.5]), specs(2, 5.0, 150, 4))
            .with_admission(AdmissionPolicy::admit_all())
            .with_seed(13)
            .with_events(vec![ControlEvent {
                at: 10.0,
                action: ControlAction::DetachStream(0),
            }]);
        let report = run_fleet(&scenario);
        let s0 = &report.streams[0];
        let s1 = &report.streams[1];
        // Detached stream's record log stops at (or shortly after) detach.
        assert!(
            s0.records.len() < 80,
            "detached stream has {} records",
            s0.records.len()
        );
        // Survivor gets more frames through than its pre-detach half share
        // (1.25 FPS × 30 s) would allow.
        assert!(
            s1.metrics.frames_processed > 45,
            "survivor processed {}",
            s1.metrics.frames_processed
        );
    }

    #[test]
    fn weighted_streams_split_throughput_by_weight() {
        // Saturated pool, weights 3:1 -> throughput ratio ≈ 3.
        let streams = vec![
            StreamSpec::new("heavy", 10.0, 300).with_window(16).with_weight(3.0),
            StreamSpec::new("light", 10.0, 300).with_window(16).with_weight(1.0),
        ];
        let scenario = Scenario::new(devices(&[2.5, 2.5]), streams)
            .with_admission(AdmissionPolicy::admit_all())
            .with_seed(17);
        let report = run_fleet(&scenario);
        let heavy = report.streams[0].metrics.frames_processed as f64;
        let light = report.streams[1].metrics.frames_processed as f64;
        let ratio = heavy / light.max(1.0);
        assert!(ratio > 2.2 && ratio < 3.8, "ratio {ratio}");
    }
}
