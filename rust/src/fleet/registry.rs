//! The fleet control plane: stream/device membership and the
//! cross-stream dispatcher.
//!
//! [`FleetRegistry`] owns the [`DevicePool`] and every [`StreamState`];
//! streams and devices attach and detach dynamically mid-run. Admission
//! shares are re-levelled on every membership change — stream attach,
//! device attach, device detach — against the pool's current Σμᵢ
//! (see [`crate::fleet::admission`]).
//!
//! Dispatch order across streams is start-time-fair queueing: every
//! stream carries a virtual time bumped by `1/weight` per dispatched
//! frame, and [`FleetRegistry::pick_stream`] serves the backlogged stream
//! with the smallest virtual time. Under contention this gives each
//! stream a share of dispatch slots proportional to its weight while
//! staying work-conserving (any backlog anywhere keeps every idle device
//! busy).

use crate::device::DeviceInstance;
use crate::fleet::admission::AdmissionPolicy;
use crate::fleet::pool::DevicePool;
use crate::fleet::stream::{StreamId, StreamSpec, StreamState};
use crate::types::FrameId;

/// A timed control-plane action (scripted scenarios, see
/// [`crate::fleet::sim::Scenario`]).
#[derive(Debug, Clone)]
pub enum ControlAction {
    AttachStream(StreamSpec),
    DetachStream(StreamId),
    AttachDevice(DeviceInstance),
    DetachDevice(usize),
}

/// `action` applied at fleet time `at`.
#[derive(Debug, Clone)]
pub struct ControlEvent {
    pub at: f64,
    pub action: ControlAction,
}

/// Membership + dispatch state for one fleet run.
pub struct FleetRegistry {
    pub pool: DevicePool,
    pub streams: Vec<StreamState>,
    pub admission: AdmissionPolicy,
}

impl FleetRegistry {
    pub fn new(devices: Vec<DeviceInstance>, admission: AdmissionPolicy) -> FleetRegistry {
        FleetRegistry {
            pool: DevicePool::new(devices),
            streams: Vec::new(),
            admission,
        }
    }

    /// Run admission for `spec` and attach it at fleet time `now`,
    /// re-levelling every active stream's share in the process (running
    /// streams may be throttled or restored, never evicted; see
    /// [`crate::fleet::admission::AdmissionPolicy::rebalance`]). Returns
    /// the new stream's id; its decision is in
    /// `self.streams[id].decision`.
    pub fn attach_stream(&mut self, spec: StreamSpec, now: f64) -> StreamId {
        let active: Vec<StreamId> = self
            .streams
            .iter()
            .filter(|s| !s.detached && s.decision.is_admitted())
            .map(|s| s.id)
            .collect();
        let mut members: Vec<(f64, f64)> = active
            .iter()
            .map(|&sid| (self.streams[sid].spec.demand(), self.streams[sid].spec.weight))
            .collect();
        members.push((spec.demand(), spec.weight));
        let levels = self
            .admission
            .rebalance(self.pool.attached_rate(), &members);
        for (k, &sid) in active.iter().enumerate() {
            self.streams[sid].decision = levels[k];
        }
        let decision = levels[levels.len() - 1];
        // Start-time-fair queueing: a joining stream's virtual time starts
        // at the current service level (min over active streams), not 0 —
        // otherwise a late joiner would monopolise dispatch until it
        // "caught up" with streams that have run for minutes.
        let base_vtime = self
            .streams
            .iter()
            .filter(|s| !s.detached && s.decision.is_admitted())
            .map(|s| s.vtime)
            .fold(f64::INFINITY, f64::min);
        let id = self.streams.len();
        let mut state = StreamState::new(id, spec, decision, now, self.pool.len());
        if base_vtime.is_finite() {
            state.vtime = base_vtime;
        }
        self.streams.push(state);
        id
    }

    /// Detach stream `id`; returns the frames still in its window so the
    /// engine can resolve them as dropped.
    pub fn detach_stream(&mut self, id: StreamId) -> Vec<FrameId> {
        let s = &mut self.streams[id];
        s.detached = true;
        s.window.drain_remaining()
    }

    /// Attach a device mid-run, growing every stream's per-device
    /// accumulators and re-levelling admission against the larger
    /// capacity (degraded streams may be restored toward full rate).
    /// Returns the device id.
    pub fn attach_device(&mut self, instance: DeviceInstance) -> usize {
        let dev = self.pool.attach(instance);
        let n = self.pool.len();
        for s in self.streams.iter_mut() {
            s.ensure_devices(n);
        }
        self.relevel_active();
        dev
    }

    /// Detach a device and re-level admission against the shrunken
    /// capacity (running streams are throttled harder, never evicted).
    pub fn detach_device(&mut self, dev: usize) {
        self.pool.detach(dev);
        self.relevel_active();
    }

    /// Recompute every active stream's share after a capacity change.
    fn relevel_active(&mut self) {
        let active: Vec<StreamId> = self
            .streams
            .iter()
            .filter(|s| !s.detached && s.decision.is_admitted())
            .map(|s| s.id)
            .collect();
        if active.is_empty() {
            return;
        }
        let members: Vec<(f64, f64)> = active
            .iter()
            .map(|&sid| (self.streams[sid].spec.demand(), self.streams[sid].spec.weight))
            .collect();
        let levels = self.admission.relevel(self.pool.attached_rate(), &members);
        for (k, &sid) in active.iter().enumerate() {
            self.streams[sid].decision = levels[k];
        }
    }

    /// The backlogged stream with the smallest weighted virtual time
    /// (ties break toward the lowest id).
    pub fn pick_stream(&self) -> Option<StreamId> {
        let mut best: Option<(f64, StreamId)> = None;
        for s in &self.streams {
            if !s.backlogged() {
                continue;
            }
            if best.map_or(true, |(bv, _)| s.vtime < bv) {
                best = Some((s.vtime, s.id));
            }
        }
        best.map(|(_, id)| id)
    }

    /// Any admitted stream with unclaimed frames?
    pub fn has_backlog(&self) -> bool {
        self.streams.iter().any(|s| s.backlogged())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{DetectorModelId, DeviceKind};
    use crate::fleet::admission::Decision;

    fn devices(rates: &[f64]) -> Vec<DeviceInstance> {
        rates
            .iter()
            .enumerate()
            .map(|(i, &r)| {
                DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, i, r)
            })
            .collect()
    }

    #[test]
    fn admission_tightens_as_streams_attach() {
        // Pool Σμ = 10, capacity 9.5; 5-FPS streams: the first is
        // admitted outright, later ones degrade, eventually reject.
        let mut reg = FleetRegistry::new(devices(&[2.5; 4]), AdmissionPolicy::default());
        let first = reg.attach_stream(StreamSpec::new("a", 5.0, 100), 0.0);
        assert!(matches!(reg.streams[first].decision, Decision::Admit { .. }));
        let mut saw_degrade = false;
        let mut saw_reject = false;
        for i in 0..12 {
            let id = reg.attach_stream(StreamSpec::new(&format!("s{i}"), 5.0, 100), 0.0);
            match reg.streams[id].decision {
                Decision::Degrade { .. } => saw_degrade = true,
                Decision::Reject => saw_reject = true,
                Decision::Admit { .. } => {}
            }
        }
        assert!(saw_degrade, "expected degradation under contention");
        assert!(saw_reject, "expected rejection under heavy overload");
    }

    #[test]
    fn pick_stream_prefers_smallest_vtime() {
        let mut reg = FleetRegistry::new(devices(&[2.5]), AdmissionPolicy::admit_all());
        let a = reg.attach_stream(StreamSpec::new("a", 5.0, 10), 0.0);
        let b = reg.attach_stream(StreamSpec::new("b", 5.0, 10), 0.0);
        reg.streams[a].window.arrive(0);
        reg.streams[b].window.arrive(0);
        reg.streams[a].vtime = 2.0;
        reg.streams[b].vtime = 1.0;
        assert_eq!(reg.pick_stream(), Some(b));
        // Ties break to the lowest id.
        reg.streams[a].vtime = 1.0;
        assert_eq!(reg.pick_stream(), Some(a));
    }

    #[test]
    fn detach_stream_drains_window() {
        let mut reg = FleetRegistry::new(devices(&[2.5]), AdmissionPolicy::admit_all());
        let id = reg.attach_stream(StreamSpec::new("a", 5.0, 10).with_window(8), 0.0);
        for f in 0..3 {
            reg.streams[id].window.arrive(f);
        }
        let drained = reg.detach_stream(id);
        assert_eq!(drained, vec![0, 1, 2]);
        assert!(reg.streams[id].detached);
        assert!(!reg.has_backlog());
    }

    #[test]
    fn late_joiner_starts_at_current_service_level() {
        let mut reg = FleetRegistry::new(devices(&[2.5]), AdmissionPolicy::admit_all());
        let a = reg.attach_stream(StreamSpec::new("a", 5.0, 100), 0.0);
        let b = reg.attach_stream(StreamSpec::new("b", 5.0, 100), 0.0);
        // Simulate a long run: both streams have dispatched many frames.
        reg.streams[a].vtime = 120.0;
        reg.streams[b].vtime = 118.0;
        let c = reg.attach_stream(StreamSpec::new("late", 5.0, 100), 30.0);
        // The newcomer inherits the minimum active vtime instead of 0, so
        // it cannot monopolise dispatch while "catching up".
        assert!((reg.streams[c].vtime - 118.0).abs() < 1e-12);
        // First-ever stream still starts at 0.
        let mut fresh = FleetRegistry::new(devices(&[2.5]), AdmissionPolicy::admit_all());
        let f = fresh.attach_stream(StreamSpec::new("f", 5.0, 100), 0.0);
        assert_eq!(fresh.streams[f].vtime, 0.0);
    }

    #[test]
    fn device_detach_tightens_and_attach_restores_admission() {
        // Pool 5 × 2.5 (capacity 11.875): two 5-FPS streams fit at full
        // rate.
        let mut reg = FleetRegistry::new(devices(&[2.5; 5]), AdmissionPolicy::default());
        let a = reg.attach_stream(StreamSpec::new("a", 5.0, 100), 0.0);
        let b = reg.attach_stream(StreamSpec::new("b", 5.0, 100), 0.0);
        assert!(matches!(reg.streams[a].decision, Decision::Admit { .. }));
        assert!(matches!(reg.streams[b].decision, Decision::Admit { .. }));
        // Losing two devices (capacity 7.125) must throttle both streams —
        // shares 3.5625 → stride 2 — keeping effective load ≤ capacity.
        reg.detach_device(3);
        reg.detach_device(4);
        for &sid in &[a, b] {
            match reg.streams[sid].decision {
                Decision::Degrade { stride, .. } => assert_eq!(stride, 2),
                ref other => panic!("expected degrade after detach, got {other:?}"),
            }
        }
        // Re-attaching capacity restores full-rate admission.
        reg.attach_device(DeviceInstance::with_rate(
            DeviceKind::Ncs2,
            DetectorModelId::Yolov3,
            5,
            2.5,
        ));
        reg.attach_device(DeviceInstance::with_rate(
            DeviceKind::Ncs2,
            DetectorModelId::Yolov3,
            6,
            2.5,
        ));
        for &sid in &[a, b] {
            assert!(
                matches!(reg.streams[sid].decision, Decision::Admit { .. }),
                "expected restore after attach, got {:?}",
                reg.streams[sid].decision
            );
        }
    }

    #[test]
    fn device_attach_grows_stream_accumulators_and_capacity() {
        let mut reg = FleetRegistry::new(devices(&[2.5]), AdmissionPolicy::admit_all());
        let id = reg.attach_stream(StreamSpec::new("a", 5.0, 10), 0.0);
        assert_eq!(reg.streams[id].device_busy.len(), 1);
        reg.attach_device(DeviceInstance::with_rate(
            DeviceKind::FastCpu,
            DetectorModelId::Yolov3,
            1,
            13.5,
        ));
        assert_eq!(reg.streams[id].device_busy.len(), 2);
        assert!((reg.pool.attached_rate() - 16.0).abs() < 1e-12);
        reg.detach_device(1);
        assert!((reg.pool.attached_rate() - 2.5).abs() < 1e-12);
    }
}
