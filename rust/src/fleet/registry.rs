//! Fleet membership state and the cross-stream dispatcher (the verbs it
//! applies live in the serialisable control plane, [`crate::control`]).
//!
//! [`FleetRegistry`] owns the [`DevicePool`] and every [`StreamState`];
//! streams and devices attach and detach dynamically mid-run. Admission
//! shares are re-levelled on every membership change — stream attach,
//! stream detach, device attach, device detach — against the pool's
//! current Σμᵢ (see [`crate::fleet::admission`]). A departing stream
//! therefore restores the remaining degraded streams toward full rate
//! (and full-quality model rungs) mid-run.
//!
//! Dispatch order across streams is start-time-fair queueing: every
//! stream carries a virtual time bumped by `1/weight` per dispatched
//! frame, and [`FleetRegistry::pick_stream`] serves the backlogged stream
//! with the smallest virtual time. Under contention this gives each
//! stream a share of dispatch slots proportional to its weight while
//! staying work-conserving (any backlog anywhere keeps every idle device
//! busy).

use crate::device::DeviceInstance;
use crate::fleet::admission::AdmissionPolicy;
use crate::fleet::pool::DevicePool;
use crate::fleet::stream::{StreamId, StreamSpec, StreamState};
use crate::types::FrameId;

// The control vocabulary (`ControlAction`, `ControlEvent`) used to be
// defined here; it now lives in the serialisable control plane and is
// re-exported for the registry's callers.
pub use crate::control::{ControlAction, ControlEvent};

/// Membership + dispatch state for one fleet run.
pub struct FleetRegistry {
    pub pool: DevicePool,
    pub streams: Vec<StreamState>,
    pub admission: AdmissionPolicy,
}

impl FleetRegistry {
    pub fn new(devices: Vec<DeviceInstance>, admission: AdmissionPolicy) -> FleetRegistry {
        FleetRegistry {
            pool: DevicePool::new(devices),
            streams: Vec::new(),
            admission,
        }
    }

    /// Run admission for `spec` and attach it at fleet time `now`,
    /// re-levelling every active stream's share in the process (running
    /// streams may be throttled or restored, never evicted; see
    /// [`crate::fleet::admission::AdmissionPolicy::rebalance`]). Returns
    /// the new stream's id; its decision is in
    /// `self.streams[id].decision`.
    pub fn attach_stream(&mut self, spec: StreamSpec, now: f64) -> StreamId {
        let active: Vec<StreamId> = self
            .streams
            .iter()
            .filter(|s| !s.detached && s.decision.is_admitted())
            .map(|s| s.id)
            .collect();
        let mut members: Vec<(f64, f64)> = active
            .iter()
            .map(|&sid| (self.streams[sid].spec.demand(), self.streams[sid].spec.weight))
            .collect();
        members.push((spec.demand(), spec.weight));
        let levels = self
            .admission
            .rebalance(self.pool.attached_rate(), &members);
        for (k, &sid) in active.iter().enumerate() {
            self.streams[sid].set_decision(levels[k], now);
        }
        let decision = levels[levels.len() - 1];
        // Start-time-fair queueing: a joining stream's virtual time starts
        // at the current service level (min over active streams), not 0 —
        // otherwise a late joiner would monopolise dispatch until it
        // "caught up" with streams that have run for minutes.
        let base_vtime = self
            .streams
            .iter()
            .filter(|s| !s.detached && s.decision.is_admitted())
            .map(|s| s.vtime)
            .fold(f64::INFINITY, f64::min);
        let id = self.streams.len();
        let mut state = StreamState::new(id, spec, decision, now, self.pool.len());
        if base_vtime.is_finite() {
            state.vtime = base_vtime;
        }
        self.streams.push(state);
        id
    }

    /// Detach stream `id` at fleet time `now`; returns the frames still
    /// in its window so the engine can resolve them as dropped. The
    /// survivors are re-levelled against the freed share: remaining
    /// degraded streams are restored toward full rate (and full-quality
    /// rungs) mid-run.
    /// Unknown ids are ignored (an empty drain): the control seam is
    /// open to scripted scenarios and third-party controllers, and one
    /// bad action must not panic a whole run.
    pub fn detach_stream(&mut self, id: StreamId, now: f64) -> Vec<FrameId> {
        let Some(s) = self.streams.get_mut(id) else {
            return Vec::new();
        };
        s.detached = true;
        let drained = s.window.drain_remaining();
        self.relevel_active(now);
        drained
    }

    /// Attach a device mid-run, growing every stream's per-device
    /// accumulators and re-levelling admission against the larger
    /// capacity (degraded streams may be restored toward full rate).
    /// Returns the device id.
    pub fn attach_device(&mut self, instance: DeviceInstance, now: f64) -> usize {
        let dev = self.pool.attach(instance);
        let n = self.pool.len();
        for s in self.streams.iter_mut() {
            s.ensure_devices(n);
        }
        self.relevel_active(now);
        dev
    }

    /// Detach a device and re-level admission against the shrunken
    /// capacity (running streams are throttled harder, never evicted).
    /// Unknown device ids are ignored, like unknown stream ids.
    pub fn detach_device(&mut self, dev: usize, now: f64) {
        if dev >= self.pool.len() {
            return;
        }
        self.pool.detach(dev);
        self.relevel_active(now);
    }

    /// Pin stream `id` to model-ladder rung `rung` (a quality-controller
    /// override): the stream keeps its current fair share, and the
    /// residual stride is recomputed for the rung's speedup. No-op for
    /// detached or rejected streams.
    pub fn set_stream_rung(&mut self, id: StreamId, rung: usize, now: f64) {
        let (share, demand) = {
            let Some(s) = self.streams.get(id) else {
                return;
            };
            if s.detached {
                return;
            }
            let Some(share) = s.decision.share() else {
                return; // rejected streams are never revived by a swap
            };
            (share, s.spec.demand())
        };
        let d = self.admission.decision_at_rung(demand, share, rung);
        self.streams[id].set_decision(d, now);
    }

    /// Recompute every active stream's share after a capacity change.
    fn relevel_active(&mut self, now: f64) {
        let active: Vec<StreamId> = self
            .streams
            .iter()
            .filter(|s| !s.detached && s.decision.is_admitted())
            .map(|s| s.id)
            .collect();
        if active.is_empty() {
            return;
        }
        let members: Vec<(f64, f64)> = active
            .iter()
            .map(|&sid| (self.streams[sid].spec.demand(), self.streams[sid].spec.weight))
            .collect();
        let levels = self.admission.relevel(self.pool.attached_rate(), &members);
        for (k, &sid) in active.iter().enumerate() {
            self.streams[sid].set_decision(levels[k], now);
        }
    }

    /// The backlogged stream with the smallest weighted virtual time
    /// (ties break toward the lowest id).
    pub fn pick_stream(&self) -> Option<StreamId> {
        let mut best: Option<(f64, StreamId)> = None;
        for s in &self.streams {
            if !s.backlogged() {
                continue;
            }
            if best.map_or(true, |(bv, _)| s.vtime < bv) {
                best = Some((s.vtime, s.id));
            }
        }
        best.map(|(_, id)| id)
    }

    /// Any admitted stream with unclaimed frames?
    pub fn has_backlog(&self) -> bool {
        self.streams.iter().any(|s| s.backlogged())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{DetectorModelId, DeviceKind};
    use crate::fleet::admission::Decision;

    fn devices(rates: &[f64]) -> Vec<DeviceInstance> {
        rates
            .iter()
            .enumerate()
            .map(|(i, &r)| {
                DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, i, r)
            })
            .collect()
    }

    #[test]
    fn admission_tightens_as_streams_attach() {
        // Pool Σμ = 10, capacity 9.5; 5-FPS streams: the first is
        // admitted outright, later ones degrade, eventually reject.
        let mut reg = FleetRegistry::new(devices(&[2.5; 4]), AdmissionPolicy::default());
        let first = reg.attach_stream(StreamSpec::new("a", 5.0, 100), 0.0);
        assert!(matches!(reg.streams[first].decision, Decision::Admit { .. }));
        let mut saw_degrade = false;
        let mut saw_reject = false;
        for i in 0..12 {
            let id = reg.attach_stream(StreamSpec::new(&format!("s{i}"), 5.0, 100), 0.0);
            match reg.streams[id].decision {
                Decision::Degrade { .. } | Decision::SwapModel { .. } => saw_degrade = true,
                Decision::Reject => saw_reject = true,
                Decision::Admit { .. } => {}
            }
        }
        assert!(saw_degrade, "expected degradation under contention");
        assert!(saw_reject, "expected rejection under heavy overload");
    }

    #[test]
    fn pick_stream_prefers_smallest_vtime() {
        let mut reg = FleetRegistry::new(devices(&[2.5]), AdmissionPolicy::admit_all());
        let a = reg.attach_stream(StreamSpec::new("a", 5.0, 10), 0.0);
        let b = reg.attach_stream(StreamSpec::new("b", 5.0, 10), 0.0);
        reg.streams[a].window.arrive(0);
        reg.streams[b].window.arrive(0);
        reg.streams[a].vtime = 2.0;
        reg.streams[b].vtime = 1.0;
        assert_eq!(reg.pick_stream(), Some(b));
        // Ties break to the lowest id.
        reg.streams[a].vtime = 1.0;
        assert_eq!(reg.pick_stream(), Some(a));
    }

    #[test]
    fn detach_stream_drains_window() {
        let mut reg = FleetRegistry::new(devices(&[2.5]), AdmissionPolicy::admit_all());
        let id = reg.attach_stream(StreamSpec::new("a", 5.0, 10).with_window(8), 0.0);
        for f in 0..3 {
            reg.streams[id].window.arrive(f);
        }
        let drained = reg.detach_stream(id, 0.0);
        assert_eq!(drained, vec![0, 1, 2]);
        assert!(reg.streams[id].detached);
        assert!(!reg.has_backlog());
    }

    #[test]
    fn late_joiner_starts_at_current_service_level() {
        let mut reg = FleetRegistry::new(devices(&[2.5]), AdmissionPolicy::admit_all());
        let a = reg.attach_stream(StreamSpec::new("a", 5.0, 100), 0.0);
        let b = reg.attach_stream(StreamSpec::new("b", 5.0, 100), 0.0);
        // Simulate a long run: both streams have dispatched many frames.
        reg.streams[a].vtime = 120.0;
        reg.streams[b].vtime = 118.0;
        let c = reg.attach_stream(StreamSpec::new("late", 5.0, 100), 30.0);
        // The newcomer inherits the minimum active vtime instead of 0, so
        // it cannot monopolise dispatch while "catching up".
        assert!((reg.streams[c].vtime - 118.0).abs() < 1e-12);
        // First-ever stream still starts at 0.
        let mut fresh = FleetRegistry::new(devices(&[2.5]), AdmissionPolicy::admit_all());
        let f = fresh.attach_stream(StreamSpec::new("f", 5.0, 100), 0.0);
        assert_eq!(fresh.streams[f].vtime, 0.0);
    }

    #[test]
    fn device_detach_tightens_and_attach_restores_admission() {
        // Pool 5 × 2.5 (capacity 11.875): two 5-FPS streams fit at full
        // rate.
        let mut reg = FleetRegistry::new(devices(&[2.5; 5]), AdmissionPolicy::default());
        let a = reg.attach_stream(StreamSpec::new("a", 5.0, 100), 0.0);
        let b = reg.attach_stream(StreamSpec::new("b", 5.0, 100), 0.0);
        assert!(matches!(reg.streams[a].decision, Decision::Admit { .. }));
        assert!(matches!(reg.streams[b].decision, Decision::Admit { .. }));
        // Losing two devices (capacity 7.125) must throttle both streams —
        // shares 3.5625 → stride 2 — keeping effective load ≤ capacity.
        reg.detach_device(3, 0.0);
        reg.detach_device(4, 0.0);
        for &sid in &[a, b] {
            match reg.streams[sid].decision {
                Decision::Degrade { stride, .. } => assert_eq!(stride, 2),
                ref other => panic!("expected degrade after detach, got {other:?}"),
            }
        }
        // Re-attaching capacity restores full-rate admission.
        reg.attach_device(
            DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, 5, 2.5),
            0.0,
        );
        reg.attach_device(
            DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, 6, 2.5),
            0.0,
        );
        for &sid in &[a, b] {
            assert!(
                matches!(reg.streams[sid].decision, Decision::Admit { .. }),
                "expected restore after attach, got {:?}",
                reg.streams[sid].decision
            );
        }
    }

    #[test]
    fn stream_detach_restores_remaining_streams() {
        // Pool capacity 7.125: two 5-FPS streams share it at stride 2
        // (share 3.5625). When one detaches mid-run, the survivor must be
        // restored to full rate — the re-level-on-detach path.
        let mut reg = FleetRegistry::new(devices(&[2.5; 3]), AdmissionPolicy::default());
        let a = reg.attach_stream(StreamSpec::new("a", 5.0, 100), 0.0);
        let b = reg.attach_stream(StreamSpec::new("b", 5.0, 100), 0.0);
        assert!(matches!(reg.streams[a].decision, Decision::Degrade { .. }));
        assert!(matches!(reg.streams[b].decision, Decision::Degrade { .. }));
        reg.detach_stream(a, 12.0);
        match reg.streams[b].decision {
            Decision::Admit { share } => assert!(share >= 5.0 - 1e-9, "share {share}"),
            ref other => panic!("survivor not restored: {other:?}"),
        }
        // The detached stream's decision is untouched (it left, it was
        // not re-levelled), and the restore is stamped in the rung log
        // only when the rung actually changed (stride streams stay rung 0).
        assert!(reg.streams[a].detached);
        assert_eq!(reg.streams[b].rung_log, vec![(0.0, 0)]);
    }

    #[test]
    fn stream_detach_restores_model_rungs() {
        // Same shape with a ladder policy: contention parks both streams
        // on rung 1; the detach restores the survivor to the full model.
        let policy = AdmissionPolicy::with_ladder(vec![1.0, 2.6, 3.2]);
        let mut reg = FleetRegistry::new(devices(&[2.5; 3]), policy);
        let a = reg.attach_stream(StreamSpec::new("a", 5.0, 100), 0.0);
        let b = reg.attach_stream(StreamSpec::new("b", 5.0, 100), 0.0);
        for &sid in &[a, b] {
            assert_eq!(reg.streams[sid].decision.rung(), 1, "{:?}", reg.streams[sid].decision);
        }
        reg.detach_stream(a, 20.0);
        assert!(matches!(reg.streams[b].decision, Decision::Admit { .. }));
        assert_eq!(reg.streams[b].rung_log, vec![(0.0, 1), (20.0, 0)]);
    }

    #[test]
    fn single_device_pool_losing_its_only_device() {
        // The pool's only device detaches: capacity 0. Running streams
        // are throttled to (effectively) nothing but never evicted, and
        // dispatch finds no idle device — no panic anywhere.
        let mut reg = FleetRegistry::new(devices(&[2.5]), AdmissionPolicy::default());
        let id = reg.attach_stream(StreamSpec::new("a", 2.0, 50), 0.0);
        assert!(matches!(reg.streams[id].decision, Decision::Admit { .. }));
        reg.detach_device(0, 5.0);
        match reg.streams[id].decision {
            Decision::Degrade { stride, share } => {
                assert_eq!(share, 0.0);
                assert!(stride >= 1_000_000, "stride {stride}");
            }
            ref other => panic!("expected throttle-to-zero, got {other:?}"),
        }
        assert!((reg.pool.attached_rate() - 0.0).abs() < 1e-12);
        // Backlogged frames exist, but no device will ever claim them.
        reg.streams[id].window.arrive(0);
        assert_eq!(reg.pool.next_idle(), None);
    }

    #[test]
    fn set_stream_rung_overrides_and_recomputes_stride() {
        let policy = AdmissionPolicy::with_ladder(vec![1.0, 2.6, 3.2]);
        let mut reg = FleetRegistry::new(devices(&[2.5, 2.5]), policy);
        let a = reg.attach_stream(StreamSpec::new("a", 5.0, 100), 0.0);
        let b = reg.attach_stream(StreamSpec::new("b", 5.0, 100), 0.0);
        assert_eq!(reg.streams[a].decision.rung(), 1);
        // Force a deeper rung: share 2.375 easily covers 5/3.2.
        reg.set_stream_rung(a, 2, 7.0);
        assert!(matches!(
            reg.streams[a].decision,
            Decision::SwapModel { rung: 2, stride: 1, .. }
        ));
        // Force back to the full model: 5 > 2.375 needs stride 3.
        reg.set_stream_rung(a, 0, 9.0);
        assert!(matches!(
            reg.streams[a].decision,
            Decision::Degrade { stride: 3, .. }
        ));
        assert_eq!(reg.streams[a].rung_log, vec![(0.0, 1), (7.0, 2), (9.0, 0)]);
        // Detached / rejected streams are left alone.
        reg.detach_stream(b, 10.0);
        let before = reg.streams[b].decision;
        reg.set_stream_rung(b, 2, 11.0);
        assert_eq!(reg.streams[b].decision, before);
    }

    #[test]
    fn out_of_range_control_ids_are_ignored_not_panics() {
        // The control seam accepts scripted and third-party actions; a
        // bad id must degrade to a no-op, not abort the run.
        let mut reg = FleetRegistry::new(devices(&[2.5]), AdmissionPolicy::default());
        let a = reg.attach_stream(StreamSpec::new("a", 2.0, 50), 0.0);
        let before = reg.streams[a].decision;
        assert!(reg.detach_stream(99, 1.0).is_empty());
        reg.detach_device(7, 2.0);
        reg.set_stream_rung(42, 1, 3.0);
        assert_eq!(reg.streams[a].decision, before);
        assert!(!reg.streams[a].detached);
        assert!((reg.pool.attached_rate() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn device_attach_grows_stream_accumulators_and_capacity() {
        let mut reg = FleetRegistry::new(devices(&[2.5]), AdmissionPolicy::admit_all());
        let id = reg.attach_stream(StreamSpec::new("a", 5.0, 10), 0.0);
        assert_eq!(reg.streams[id].device_busy.len(), 1);
        reg.attach_device(
            DeviceInstance::with_rate(DeviceKind::FastCpu, DetectorModelId::Yolov3, 1, 13.5),
            0.0,
        );
        assert_eq!(reg.streams[id].device_busy.len(), 2);
        assert!((reg.pool.attached_rate() - 16.0).abs() < 1e-12);
        reg.detach_device(1, 0.0);
        assert!((reg.pool.attached_rate() - 2.5).abs() < 1e-12);
    }
}
