//! Multi-stream serving over a shared heterogeneous device pool.
//!
//! The paper parallelises detection for *one* video stream; this
//! subsystem serves **many concurrent streams** from one pool of
//! detector replicas — the regime where runtime adaptation and
//! deployment search actually matter at the edge. Core pieces:
//!
//! * [`stream`] — per-stream state: paced source, bounded freshness
//!   window, its own sequence synchronizer, per-stream run metrics.
//! * [`pool`] — the shared device pool: work-conserving dispatch,
//!   per-device accounting, mid-run attach/detach.
//! * [`admission`] — admit / degrade / reject when Σλₛ exceeds Σμᵢ,
//!   with weighted max-min fair sharing of detector throughput.
//! * [`registry`] — membership state (dynamic stream/device attach &
//!   detach) plus the weighted start-time-fair dispatcher. The control
//!   *vocabulary* it applies (`ControlAction`/`ControlEvent`) lives in
//!   the serialisable control plane, [`crate::control`].
//! * [`metrics`] — fleet aggregates: per-stream σ and latency
//!   percentiles, drop rates, device utilisation, Jain fairness index.
//! * [`sim`] — virtual-time engine (DES-backed, milliseconds per run):
//!   timing, fairness and elasticity studies at any scale; exposes the
//!   [`sim::FleetController`] hook that `crate::autoscale` drives for
//!   closed-loop device scaling and model-ladder swaps.
//! * [`serve`] — wall-clock engine (thread-backed, real detectors):
//!   the live multi-stream serving pipeline.
//!
//! Invariants shared with the single-stream pipeline: every frame that
//! enters a stream gets exactly one output record, in frame order, with
//! dropped frames carrying stale detections; dispatch is work-conserving,
//! so saturated aggregate throughput approaches Σμᵢ.

pub mod admission;
pub mod metrics;
pub mod pool;
pub mod registry;
pub mod serve;
pub mod sim;
pub mod stream;

pub use admission::{AdmissionMode, AdmissionPolicy, Decision, DegradeMode};
pub use metrics::{jain_index, FleetReport, StreamReport};
pub use pool::{DevicePool, Job};
pub use registry::FleetRegistry;
pub use serve::{serve_fleet, serve_fleet_logged, serve_fleet_traced, FleetServeConfig};
pub use sim::{run_fleet, run_fleet_with, FleetController, FleetRunOutput, Scenario};

// Control-plane vocabulary: defined in `crate::control`, re-exported
// here because fleet callers have always imported it from this module.
pub use crate::control::{ControlAction, ControlEvent, ControlOrigin, ControlRecord};
pub use stream::{StreamId, StreamSpec};
