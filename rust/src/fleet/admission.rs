//! Admission control for multi-stream serving.
//!
//! When the aggregate offered rate Σλₛ exceeds the pool capacity Σμᵢ,
//! something has to give. The policy here computes each stream's
//! **weighted max-min fair share** of detector throughput (progressive
//! water-filling: no stream gets more than it asks for, unused capacity
//! is redistributed, and every unsatisfied stream ends with the same
//! normalised share `shareₛ / wₛ`), then maps the candidate's share to a
//! decision:
//!
//! * share ≥ demand   → [`Decision::Admit`] (full rate),
//! * share ≥ min_rate → [`Decision::Degrade`] — the stream is admitted
//!   but must subsample its input, keeping every `stride`-th frame so its
//!   effective demand fits its share,
//! * otherwise        → [`Decision::Reject`].
//!
//! On every stream attach ([`AdmissionPolicy::rebalance`]) and on every
//! device attach/detach ([`AdmissionPolicy::relevel`]) the fair shares
//! of **all** active streams are re-levelled: running streams may be
//! throttled further or restored to full rate, but are never evicted —
//! only a joining candidate can be rejected (and a rejected stream is
//! never revived). This keeps the admitted effective load Σ λₛ/strideₛ
//! at or below the target capacity as streams arrive and the pool
//! grows or shrinks, which is what bounds admitted streams' output
//! latency under overload.

/// Whether the policy actually gates streams or waves everything in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionMode {
    /// Apply the share thresholds below.
    Enforce,
    /// Admit every stream at full rate (overload shows up as frame drops).
    AdmitAll,
}

/// Admission policy parameters.
#[derive(Debug, Clone)]
pub struct AdmissionPolicy {
    /// Fraction of the pool rate Σμᵢ the admitted load may claim
    /// (headroom below 1.0 absorbs service-time jitter).
    pub target_utilization: f64,
    /// Streams whose fair share falls below this rate (FPS) are rejected
    /// rather than degraded into uselessness.
    pub min_rate: f64,
    pub mode: AdmissionMode,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        AdmissionPolicy {
            target_utilization: 0.95,
            min_rate: 1.0,
            mode: AdmissionMode::Enforce,
        }
    }
}

impl AdmissionPolicy {
    /// Policy that admits everything (baseline / capacity measurement).
    pub fn admit_all() -> AdmissionPolicy {
        AdmissionPolicy {
            mode: AdmissionMode::AdmitAll,
            ..AdmissionPolicy::default()
        }
    }

    /// Decide the candidate's fate against a static snapshot (convenience
    /// wrapper over [`AdmissionPolicy::rebalance`]). `pool_rate` is the
    /// attached Σμᵢ; `admitted` holds the currently admitted streams'
    /// `(demand λ, weight)` pairs; `candidate` is the joining stream's.
    pub fn decide(&self, pool_rate: f64, admitted: &[(f64, f64)], candidate: (f64, f64)) -> Decision {
        let mut members: Vec<(f64, f64)> = admitted.to_vec();
        members.push(candidate);
        let levels = self.rebalance(pool_rate, &members);
        levels[levels.len() - 1]
    }

    /// Re-level every member's decision. `members` lists `(demand λ,
    /// weight)` pairs for the currently active admitted streams, with the
    /// **joining candidate last**. Running streams are throttled to their
    /// fresh fair share (never rejected); only the candidate may be
    /// rejected, in which case the survivors are levelled without it.
    pub fn rebalance(&self, pool_rate: f64, members: &[(f64, f64)]) -> Vec<Decision> {
        if self.mode == AdmissionMode::AdmitAll {
            return members
                .iter()
                .map(|&(d, _)| Decision::Admit { share: d })
                .collect();
        }
        let n = members.len();
        if n == 0 {
            return Vec::new();
        }
        let capacity = (pool_rate * self.target_utilization).max(0.0);
        let demands: Vec<f64> = members.iter().map(|&(d, _)| d).collect();
        let weights: Vec<f64> = members.iter().map(|&(_, w)| w).collect();
        let shares = weighted_max_min_shares(capacity, &demands, &weights);

        let cand_share = shares[n - 1];
        let cand_demand = demands[n - 1];
        let candidate = if cand_share + 1e-9 >= cand_demand {
            Decision::Admit { share: cand_share }
        } else if cand_share >= self.min_rate {
            Decision::Degrade {
                stride: stride_for(cand_demand, cand_share),
                share: cand_share,
            }
        } else {
            Decision::Reject
        };

        let mut out = Vec::with_capacity(n);
        if matches!(candidate, Decision::Reject) {
            // The candidate never joins, so the survivors keep the water
            // level computed without it.
            let shares2 =
                weighted_max_min_shares(capacity, &demands[..n - 1], &weights[..n - 1]);
            for i in 0..n - 1 {
                out.push(throttled(shares2[i], demands[i]));
            }
        } else {
            for i in 0..n - 1 {
                out.push(throttled(shares[i], demands[i]));
            }
        }
        out.push(candidate);
        out
    }

    /// Re-level all active members with **no candidate** — applied after
    /// pool capacity changes (device attach/detach). Nobody is rejected:
    /// shrinking capacity throttles running streams harder; growing
    /// capacity restores throttled streams toward full rate.
    pub fn relevel(&self, pool_rate: f64, members: &[(f64, f64)]) -> Vec<Decision> {
        if self.mode == AdmissionMode::AdmitAll {
            return members
                .iter()
                .map(|&(d, _)| Decision::Admit { share: d })
                .collect();
        }
        if members.is_empty() {
            return Vec::new();
        }
        let capacity = (pool_rate * self.target_utilization).max(0.0);
        let demands: Vec<f64> = members.iter().map(|&(d, _)| d).collect();
        let weights: Vec<f64> = members.iter().map(|&(_, w)| w).collect();
        let shares = weighted_max_min_shares(capacity, &demands, &weights);
        demands
            .iter()
            .zip(&shares)
            .map(|(&d, &s)| throttled(s, d))
            .collect()
    }
}

fn stride_for(demand: f64, share: f64) -> u64 {
    (demand / share.max(1e-9)).ceil().max(1.0) as u64
}

/// Level for an already-running stream: full rate if its share covers the
/// demand, otherwise throttled — even below `min_rate` (running streams
/// are never evicted by a newcomer).
fn throttled(share: f64, demand: f64) -> Decision {
    if share + 1e-9 >= demand {
        Decision::Admit { share }
    } else {
        Decision::Degrade {
            stride: stride_for(demand, share),
            share,
        }
    }
}

/// Outcome of admission for one stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Decision {
    /// Full-rate admission; `share` is the fair share backing it.
    Admit { share: f64 },
    /// Admitted at reduced rate: keep every `stride`-th frame.
    Degrade { stride: u64, share: f64 },
    /// Not admitted; every frame of the stream is dropped.
    Reject,
}

impl Decision {
    pub fn is_admitted(&self) -> bool {
        !matches!(self, Decision::Reject)
    }

    /// Input subsampling stride implied by the decision (1 = keep all).
    pub fn stride(&self) -> u64 {
        match self {
            Decision::Degrade { stride, .. } => (*stride).max(1),
            _ => 1,
        }
    }

    pub fn label(&self) -> String {
        match self {
            Decision::Admit { .. } => "admit".to_string(),
            Decision::Degrade { stride, .. } => format!("degrade(1/{stride})"),
            Decision::Reject => "reject".to_string(),
        }
    }
}

/// Weighted max-min fair allocation of `capacity` across streams with the
/// given `demands` and (strictly positive) `weights`, by progressive
/// water-filling. Guarantees (up to float tolerance):
///
/// 1. feasibility: Σ shareᵢ = min(Σ demandᵢ, capacity);
/// 2. demand cap: shareᵢ ≤ demandᵢ;
/// 3. if Σ demandᵢ ≤ capacity every stream gets exactly its demand;
/// 4. bottleneck fairness: all streams left unsatisfied have equal
///    normalised shares shareᵢ/wᵢ, no smaller than any satisfied
///    stream's normalised share.
pub fn weighted_max_min_shares(capacity: f64, demands: &[f64], weights: &[f64]) -> Vec<f64> {
    assert_eq!(demands.len(), weights.len(), "one weight per demand");
    assert!(
        weights.iter().all(|&w| w > 0.0),
        "weights must be strictly positive"
    );
    let n = demands.len();
    let mut shares = vec![0.0f64; n];
    if n == 0 || capacity <= 0.0 {
        return shares;
    }
    let mut remaining = capacity;
    loop {
        let active: Vec<usize> = (0..n)
            .filter(|&i| shares[i] < demands[i] - 1e-12)
            .collect();
        if active.is_empty() || remaining <= 1e-12 {
            break;
        }
        let wsum: f64 = active.iter().map(|&i| weights[i]).sum();
        let per_weight = remaining / wsum;
        // Cap every stream whose residual demand fits inside its
        // proportional slice of this round; redistribute what they
        // declined in the next round.
        let mut capped_any = false;
        for &i in &active {
            let slice = per_weight * weights[i];
            let need = demands[i] - shares[i];
            if need <= slice + 1e-12 {
                shares[i] = demands[i];
                remaining -= need;
                capped_any = true;
            }
        }
        if !capped_any {
            // Everyone still wants more than their slice: hand out the
            // slices and the water level is final.
            for &i in &active {
                shares[i] += per_weight * weights[i];
            }
            break;
        }
    }
    shares
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};

    #[test]
    fn under_capacity_everyone_satisfied() {
        let s = weighted_max_min_shares(100.0, &[10.0, 20.0, 5.0], &[1.0, 1.0, 1.0]);
        assert_eq!(s, vec![10.0, 20.0, 5.0]);
    }

    #[test]
    fn equal_weights_split_evenly_under_saturation() {
        let s = weighted_max_min_shares(12.0, &[100.0, 100.0, 100.0], &[1.0, 1.0, 1.0]);
        for x in &s {
            assert!((x - 4.0).abs() < 1e-9, "{s:?}");
        }
    }

    #[test]
    fn weights_bias_the_split() {
        let s = weighted_max_min_shares(12.0, &[100.0, 100.0], &[3.0, 1.0]);
        assert!((s[0] - 9.0).abs() < 1e-9, "{s:?}");
        assert!((s[1] - 3.0).abs() < 1e-9, "{s:?}");
    }

    #[test]
    fn small_demand_releases_capacity_to_others() {
        // Stream 0 only wants 1; the rest of the 12 goes to stream 1.
        let s = weighted_max_min_shares(12.0, &[1.0, 100.0], &[1.0, 1.0]);
        assert!((s[0] - 1.0).abs() < 1e-9, "{s:?}");
        assert!((s[1] - 11.0).abs() < 1e-9, "{s:?}");
    }

    #[test]
    fn empty_and_zero_capacity() {
        assert!(weighted_max_min_shares(10.0, &[], &[]).is_empty());
        let s = weighted_max_min_shares(0.0, &[5.0, 5.0], &[1.0, 1.0]);
        assert_eq!(s, vec![0.0, 0.0]);
    }

    #[test]
    fn prop_feasible_capped_and_work_conserving() {
        check("max-min shares feasible", Config::default(), |rng| {
            let n = rng.int_in(1, 10) as usize;
            let capacity = rng.range(0.0, 50.0);
            let demands: Vec<f64> = (0..n).map(|_| rng.range(0.0, 20.0)).collect();
            let weights: Vec<f64> = (0..n).map(|_| rng.range(0.1, 5.0)).collect();
            let shares = weighted_max_min_shares(capacity, &demands, &weights);
            let total: f64 = shares.iter().sum();
            let demand_total: f64 = demands.iter().sum();
            if total > capacity + 1e-6 {
                return Err(format!("overcommitted: {total} > {capacity}"));
            }
            let expected = demand_total.min(capacity);
            if (total - expected).abs() > 1e-6 {
                return Err(format!(
                    "not work-conserving: allocated {total}, expected {expected}"
                ));
            }
            for (i, (&s, &d)) in shares.iter().zip(&demands).enumerate() {
                if s > d + 1e-9 {
                    return Err(format!("stream {i} got {s} > demand {d}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_bottleneck_streams_have_equal_normalised_shares() {
        check("max-min bottleneck fairness", Config::default(), |rng| {
            let n = rng.int_in(2, 8) as usize;
            let capacity = rng.range(1.0, 20.0);
            let demands: Vec<f64> = (0..n).map(|_| rng.range(0.5, 15.0)).collect();
            let weights: Vec<f64> = (0..n).map(|_| rng.range(0.2, 4.0)).collect();
            let shares = weighted_max_min_shares(capacity, &demands, &weights);
            let unsatisfied: Vec<usize> = (0..n)
                .filter(|&i| shares[i] < demands[i] - 1e-6)
                .collect();
            // All unsatisfied streams share one normalised water level...
            for w in unsatisfied.windows(2) {
                let a = shares[w[0]] / weights[w[0]];
                let b = shares[w[1]] / weights[w[1]];
                if (a - b).abs() > 1e-6 {
                    return Err(format!("unequal levels {a} vs {b}"));
                }
            }
            // ...and no satisfied stream sits above it.
            if let Some(&u) = unsatisfied.first() {
                let level = shares[u] / weights[u];
                for i in 0..n {
                    if shares[i] >= demands[i] - 1e-6
                        && shares[i] / weights[i] > level + 1e-6
                    {
                        return Err(format!(
                            "satisfied stream {i} above the water level"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn decide_admits_with_headroom() {
        let p = AdmissionPolicy::default();
        match p.decide(20.0, &[], (5.0, 1.0)) {
            Decision::Admit { share } => assert!(share >= 5.0 - 1e-9),
            other => panic!("expected admit, got {other:?}"),
        }
    }

    #[test]
    fn decide_degrades_under_contention() {
        let p = AdmissionPolicy::default();
        // Capacity 9.5, three equal streams of 5: share ≈ 3.17 each.
        let d = p.decide(10.0, &[(5.0, 1.0), (5.0, 1.0)], (5.0, 1.0));
        match d {
            Decision::Degrade { stride, share } => {
                assert_eq!(stride, 2, "{d:?}");
                assert!(share > 3.0 && share < 3.3, "{d:?}");
            }
            other => panic!("expected degrade, got {other:?}"),
        }
        assert!(d.is_admitted());
        assert_eq!(d.stride(), 2);
    }

    #[test]
    fn decide_rejects_below_min_rate() {
        let p = AdmissionPolicy::default();
        let admitted: Vec<(f64, f64)> = (0..9).map(|_| (5.0, 1.0)).collect();
        // Capacity 9.5 over 10 claimants: share 0.95 < min_rate 1.0.
        let d = p.decide(10.0, &admitted, (5.0, 1.0));
        assert_eq!(d, Decision::Reject);
        assert!(!d.is_admitted());
    }

    #[test]
    fn rebalance_throttles_running_streams_but_never_evicts() {
        let p = AdmissionPolicy::default();
        // Capacity 9.5: four 5-FPS members -> everyone levels to 2.375.
        let members = [(5.0, 1.0); 4];
        let levels = p.rebalance(10.0, &members);
        assert_eq!(levels.len(), 4);
        for d in &levels[..3] {
            match d {
                Decision::Degrade { stride, share } => {
                    assert_eq!(*stride, 3, "{d:?}");
                    assert!((share - 2.375).abs() < 1e-9);
                }
                other => panic!("running stream evicted or admitted: {other:?}"),
            }
        }
        // Admitted effective load fits the capacity.
        let effective: f64 = members
            .iter()
            .zip(&levels)
            .filter(|(_, d)| d.is_admitted())
            .map(|(&(demand, _), d)| demand / d.stride() as f64)
            .sum();
        assert!(effective <= 9.5 + 1e-9, "effective {effective}");
    }

    #[test]
    fn rebalance_rejected_candidate_leaves_survivors_at_old_level() {
        let p = AdmissionPolicy::default();
        // Nine members exhaust capacity 9.5 at share ~1.06 each; the
        // tenth pushes shares to 0.95 < min_rate and is rejected, so the
        // nine keep the 9-way level.
        let mut members = vec![(5.0, 1.0); 9];
        members.push((5.0, 1.0));
        let levels = p.rebalance(10.0, &members);
        assert_eq!(levels[9], Decision::Reject);
        for d in &levels[..9] {
            match d {
                Decision::Degrade { share, .. } => {
                    assert!((share - 9.5 / 9.0).abs() < 1e-9, "{d:?}")
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn admit_all_never_gates() {
        let p = AdmissionPolicy::admit_all();
        let d = p.decide(0.0, &[(100.0, 1.0)], (50.0, 1.0));
        assert!(matches!(d, Decision::Admit { .. }));
    }

    #[test]
    fn decision_labels() {
        assert_eq!(Decision::Admit { share: 5.0 }.label(), "admit");
        assert_eq!(Decision::Degrade { stride: 3, share: 1.0 }.label(), "degrade(1/3)");
        assert_eq!(Decision::Reject.label(), "reject");
    }
}
