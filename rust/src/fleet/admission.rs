//! Admission control for multi-stream serving.
//!
//! When the aggregate offered rate Σλₛ exceeds the pool capacity Σμᵢ,
//! something has to give. The policy here computes each stream's
//! **weighted max-min fair share** of detector throughput (progressive
//! water-filling: no stream gets more than it asks for, unused capacity
//! is redistributed, and every unsatisfied stream ends with the same
//! normalised share `shareₛ / wₛ`), then maps the candidate's share to a
//! decision:
//!
//! * share ≥ demand   → [`Decision::Admit`] (full rate),
//! * share ≥ min_rate → the stream is admitted but must shrink its
//!   effective demand to its share. How it shrinks is the policy's
//!   [`DegradeMode`]: classic frame-stride subsampling
//!   ([`Decision::Degrade`]), or — quality-aware admission — a **model
//!   swap** down a ladder of faster, lower-mAP detector variants
//!   ([`Decision::SwapModel`]), falling back to a residual stride only
//!   when even the fastest rung cannot fit the share,
//! * otherwise        → [`Decision::Reject`].
//!
//! On every stream attach ([`AdmissionPolicy::rebalance`]) and on every
//! device attach/detach ([`AdmissionPolicy::relevel`]) the fair shares
//! of **all** active streams are re-levelled: running streams may be
//! throttled further or restored to full rate, but are never evicted —
//! only a joining candidate can be rejected (and a rejected stream is
//! never revived). This keeps the admitted effective load Σ λₛ/strideₛ
//! at or below the target capacity as streams arrive and the pool
//! grows or shrinks, which is what bounds admitted streams' output
//! latency under overload.

/// Whether the policy actually gates streams or waves everything in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionMode {
    /// Apply the share thresholds below.
    Enforce,
    /// Admit every stream at full rate (overload shows up as frame drops).
    AdmitAll,
}

/// How an admitted-but-unsatisfied stream shrinks its effective demand
/// to its fair share.
#[derive(Debug, Clone, PartialEq)]
pub enum DegradeMode {
    /// Subsample the input: keep every `stride`-th frame.
    Stride,
    /// Walk a model ladder first: swap the stream onto a faster,
    /// lower-mAP detector variant, which divides the stream's effective
    /// demand (in base-model frame cost) by the rung's service-rate
    /// `speedups[rung]`. `speedups` is ascending with `speedups[0] =
    /// 1.0` (the full-quality model); see
    /// `crate::autoscale::ladder::ModelLadder::speedups`. A residual
    /// stride is applied only when even the fastest rung cannot fit.
    ModelSwap { speedups: Vec<f64> },
}

/// Admission policy parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct AdmissionPolicy {
    /// Fraction of the pool rate Σμᵢ the admitted load may claim
    /// (headroom below 1.0 absorbs service-time jitter).
    pub target_utilization: f64,
    /// Streams whose fair share falls below this rate (FPS) are rejected
    /// rather than degraded into uselessness.
    pub min_rate: f64,
    pub mode: AdmissionMode,
    /// How unsatisfied streams trade demand for their share.
    pub degrade: DegradeMode,
    /// Forecast-armed burst hold: while true, a stream whose fair share
    /// falls short is admitted at full rate anyway instead of being
    /// degraded — the queue absorbs the transient. The shard runner arms
    /// this per gossip epoch only when a tight forecast says the burst
    /// clears within its hold window ([`crate::forecast::should_hold`]);
    /// it is runtime state, never serialised, and rejection of joining
    /// candidates is unaffected. Degrade/restore churn costs a model
    /// swap or stride change *twice* for a burst that was going to clear
    /// anyway; holding costs a bounded latency bump.
    pub hold: bool,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        AdmissionPolicy {
            target_utilization: 0.95,
            min_rate: 1.0,
            mode: AdmissionMode::Enforce,
            degrade: DegradeMode::Stride,
            hold: false,
        }
    }
}

impl AdmissionPolicy {
    /// Policy that admits everything (baseline / capacity measurement).
    pub fn admit_all() -> AdmissionPolicy {
        AdmissionPolicy {
            mode: AdmissionMode::AdmitAll,
            ..AdmissionPolicy::default()
        }
    }

    /// Enforcing policy that degrades by model swap down `speedups`
    /// (quality-aware admission) instead of frame stride.
    pub fn with_ladder(speedups: Vec<f64>) -> AdmissionPolicy {
        AdmissionPolicy {
            degrade: DegradeMode::ModelSwap { speedups },
            ..AdmissionPolicy::default()
        }
    }

    /// Service-rate multiplier of ladder rung `rung` (1.0 when the
    /// policy has no ladder; the fastest rung for out-of-range indices).
    pub fn rung_speedup(&self, rung: usize) -> f64 {
        match &self.degrade {
            DegradeMode::Stride => 1.0,
            DegradeMode::ModelSwap { speedups } => speedups
                .get(rung)
                .or_else(|| speedups.last())
                .copied()
                .unwrap_or(1.0),
        }
    }

    /// Deepest ladder rung this policy can swap to (0 = no ladder).
    pub fn max_rung(&self) -> usize {
        match &self.degrade {
            DegradeMode::Stride => 0,
            DegradeMode::ModelSwap { speedups } => speedups.len().saturating_sub(1),
        }
    }

    /// Decision for a stream pinned at ladder `rung` (the quality
    /// controller's override path): the residual stride is whatever the
    /// rung's scaled demand still needs to fit `share`. `rung` is
    /// clamped to the deepest real rung so a decision never records a
    /// rung the ladder cannot actually serve.
    pub fn decision_at_rung(&self, demand: f64, share: f64, rung: usize) -> Decision {
        let rung = rung.min(self.max_rung());
        let k = self.rung_speedup(rung).max(1e-9);
        let eff = demand / k;
        let stride = if eff <= share + 1e-9 {
            1
        } else {
            stride_for(eff, share)
        };
        if rung == 0 {
            if stride <= 1 {
                Decision::Admit { share }
            } else {
                Decision::Degrade { stride, share }
            }
        } else {
            Decision::SwapModel { rung, stride, share }
        }
    }

    /// Level for an admitted stream: full rate if its share covers the
    /// demand; otherwise degrade per [`DegradeMode`] — ladder first
    /// (cheapest sufficient rung), stride as the last resort.
    fn level(&self, share: f64, demand: f64) -> Decision {
        if share + 1e-9 >= demand {
            return Decision::Admit { share };
        }
        if self.hold {
            // Burst hold: the forecast says this overload clears within
            // a window, so keep the stream at full rate rather than
            // paying the degrade-then-restore round trip.
            return Decision::Admit { share };
        }
        match &self.degrade {
            DegradeMode::Stride => Decision::Degrade {
                stride: stride_for(demand, share),
                share,
            },
            DegradeMode::ModelSwap { speedups } => {
                for (rung, &k) in speedups.iter().enumerate().skip(1) {
                    if demand / k.max(1e-9) <= share + 1e-9 {
                        return Decision::SwapModel { rung, stride: 1, share };
                    }
                }
                match speedups.len().checked_sub(1) {
                    Some(last) if last > 0 => {
                        let k = speedups[last].max(1e-9);
                        Decision::SwapModel {
                            rung: last,
                            stride: stride_for(demand / k, share),
                            share,
                        }
                    }
                    // Degenerate ladder (empty or just the full model):
                    // behaves like stride mode.
                    _ => Decision::Degrade {
                        stride: stride_for(demand, share),
                        share,
                    },
                }
            }
        }
    }

    /// Decide the candidate's fate against a static snapshot (convenience
    /// wrapper over [`AdmissionPolicy::rebalance`]). `pool_rate` is the
    /// attached Σμᵢ; `admitted` holds the currently admitted streams'
    /// `(demand λ, weight)` pairs; `candidate` is the joining stream's.
    pub fn decide(&self, pool_rate: f64, admitted: &[(f64, f64)], candidate: (f64, f64)) -> Decision {
        let mut members: Vec<(f64, f64)> = admitted.to_vec();
        members.push(candidate);
        let levels = self.rebalance(pool_rate, &members);
        levels[levels.len() - 1]
    }

    /// Re-level every member's decision. `members` lists `(demand λ,
    /// weight)` pairs for the currently active admitted streams, with the
    /// **joining candidate last**. Running streams are throttled to their
    /// fresh fair share (never rejected); only the candidate may be
    /// rejected, in which case the survivors are levelled without it.
    pub fn rebalance(&self, pool_rate: f64, members: &[(f64, f64)]) -> Vec<Decision> {
        if self.mode == AdmissionMode::AdmitAll {
            return members
                .iter()
                .map(|&(d, _)| Decision::Admit { share: d })
                .collect();
        }
        let n = members.len();
        if n == 0 {
            return Vec::new();
        }
        let capacity = (pool_rate * self.target_utilization).max(0.0);
        let demands: Vec<f64> = members.iter().map(|&(d, _)| d).collect();
        let weights: Vec<f64> = members.iter().map(|&(_, w)| w).collect();
        let shares = weighted_max_min_shares(capacity, &demands, &weights);

        let cand_share = shares[n - 1];
        let cand_demand = demands[n - 1];
        let candidate = if cand_share >= self.min_rate || cand_share + 1e-9 >= cand_demand {
            self.level(cand_share, cand_demand)
        } else {
            Decision::Reject
        };

        let mut out = Vec::with_capacity(n);
        if matches!(candidate, Decision::Reject) {
            // The candidate never joins, so the survivors keep the water
            // level computed without it.
            let shares2 =
                weighted_max_min_shares(capacity, &demands[..n - 1], &weights[..n - 1]);
            for i in 0..n - 1 {
                out.push(self.level(shares2[i], demands[i]));
            }
        } else {
            for i in 0..n - 1 {
                out.push(self.level(shares[i], demands[i]));
            }
        }
        out.push(candidate);
        out
    }

    /// Re-level all active members with **no candidate** — applied after
    /// pool capacity changes (device attach/detach). Nobody is rejected:
    /// shrinking capacity throttles running streams harder; growing
    /// capacity restores throttled streams toward full rate.
    pub fn relevel(&self, pool_rate: f64, members: &[(f64, f64)]) -> Vec<Decision> {
        if self.mode == AdmissionMode::AdmitAll {
            return members
                .iter()
                .map(|&(d, _)| Decision::Admit { share: d })
                .collect();
        }
        if members.is_empty() {
            return Vec::new();
        }
        let capacity = (pool_rate * self.target_utilization).max(0.0);
        let demands: Vec<f64> = members.iter().map(|&(d, _)| d).collect();
        let weights: Vec<f64> = members.iter().map(|&(_, w)| w).collect();
        let shares = weighted_max_min_shares(capacity, &demands, &weights);
        demands
            .iter()
            .zip(&shares)
            .map(|(&d, &s)| self.level(s, d))
            .collect()
    }
}

fn stride_for(demand: f64, share: f64) -> u64 {
    (demand / share.max(1e-9)).ceil().max(1.0) as u64
}

/// Outcome of admission for one stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Decision {
    /// Full-rate admission; `share` is the fair share backing it.
    Admit { share: f64 },
    /// Admitted at reduced rate: keep every `stride`-th frame.
    Degrade { stride: u64, share: f64 },
    /// Admitted on ladder rung `rung` (a faster, lower-mAP model
    /// variant), keeping every `stride`-th frame (1 = all frames; > 1
    /// only when even the fastest rung cannot fit the share).
    SwapModel { rung: usize, stride: u64, share: f64 },
    /// Not admitted; every frame of the stream is dropped.
    Reject,
}

impl Decision {
    pub fn is_admitted(&self) -> bool {
        !matches!(self, Decision::Reject)
    }

    /// Input subsampling stride implied by the decision (1 = keep all).
    pub fn stride(&self) -> u64 {
        match self {
            Decision::Degrade { stride, .. } | Decision::SwapModel { stride, .. } => {
                (*stride).max(1)
            }
            _ => 1,
        }
    }

    /// Ladder rung the stream runs at (0 = the full-quality model).
    pub fn rung(&self) -> usize {
        match self {
            Decision::SwapModel { rung, .. } => *rung,
            _ => 0,
        }
    }

    /// Fair share backing an admitted decision (`None` for rejects).
    pub fn share(&self) -> Option<f64> {
        match self {
            Decision::Admit { share }
            | Decision::Degrade { share, .. }
            | Decision::SwapModel { share, .. } => Some(*share),
            Decision::Reject => None,
        }
    }

    pub fn label(&self) -> String {
        match self {
            Decision::Admit { .. } => "admit".to_string(),
            Decision::Degrade { stride, .. } => format!("degrade(1/{stride})"),
            Decision::SwapModel { rung, stride, .. } if *stride > 1 => {
                format!("swap(rung {rung}, 1/{stride})")
            }
            Decision::SwapModel { rung, .. } => format!("swap(rung {rung})"),
            Decision::Reject => "reject".to_string(),
        }
    }
}

/// Weighted max-min fair allocation of `capacity` across streams with the
/// given `demands` and (strictly positive) `weights`, by progressive
/// water-filling. Guarantees (up to float tolerance):
///
/// 1. feasibility: Σ shareᵢ = min(Σ demandᵢ, capacity);
/// 2. demand cap: shareᵢ ≤ demandᵢ;
/// 3. if Σ demandᵢ ≤ capacity every stream gets exactly its demand;
/// 4. bottleneck fairness: all streams left unsatisfied have equal
///    normalised shares shareᵢ/wᵢ, no smaller than any satisfied
///    stream's normalised share.
pub fn weighted_max_min_shares(capacity: f64, demands: &[f64], weights: &[f64]) -> Vec<f64> {
    assert_eq!(demands.len(), weights.len(), "one weight per demand");
    assert!(
        weights.iter().all(|&w| w > 0.0),
        "weights must be strictly positive"
    );
    let n = demands.len();
    let mut shares = vec![0.0f64; n];
    if n == 0 || capacity <= 0.0 {
        return shares;
    }
    let mut remaining = capacity;
    loop {
        let active: Vec<usize> = (0..n)
            .filter(|&i| shares[i] < demands[i] - 1e-12)
            .collect();
        if active.is_empty() || remaining <= 1e-12 {
            break;
        }
        let wsum: f64 = active.iter().map(|&i| weights[i]).sum();
        let per_weight = remaining / wsum;
        // Cap every stream whose residual demand fits inside its
        // proportional slice of this round; redistribute what they
        // declined in the next round.
        let mut capped_any = false;
        for &i in &active {
            let slice = per_weight * weights[i];
            let need = demands[i] - shares[i];
            if need <= slice + 1e-12 {
                shares[i] = demands[i];
                remaining -= need;
                capped_any = true;
            }
        }
        if !capped_any {
            // Everyone still wants more than their slice: hand out the
            // slices and the water level is final.
            for &i in &active {
                shares[i] += per_weight * weights[i];
            }
            break;
        }
    }
    shares
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};

    #[test]
    fn under_capacity_everyone_satisfied() {
        let s = weighted_max_min_shares(100.0, &[10.0, 20.0, 5.0], &[1.0, 1.0, 1.0]);
        assert_eq!(s, vec![10.0, 20.0, 5.0]);
    }

    #[test]
    fn equal_weights_split_evenly_under_saturation() {
        let s = weighted_max_min_shares(12.0, &[100.0, 100.0, 100.0], &[1.0, 1.0, 1.0]);
        for x in &s {
            assert!((x - 4.0).abs() < 1e-9, "{s:?}");
        }
    }

    #[test]
    fn weights_bias_the_split() {
        let s = weighted_max_min_shares(12.0, &[100.0, 100.0], &[3.0, 1.0]);
        assert!((s[0] - 9.0).abs() < 1e-9, "{s:?}");
        assert!((s[1] - 3.0).abs() < 1e-9, "{s:?}");
    }

    #[test]
    fn small_demand_releases_capacity_to_others() {
        // Stream 0 only wants 1; the rest of the 12 goes to stream 1.
        let s = weighted_max_min_shares(12.0, &[1.0, 100.0], &[1.0, 1.0]);
        assert!((s[0] - 1.0).abs() < 1e-9, "{s:?}");
        assert!((s[1] - 11.0).abs() < 1e-9, "{s:?}");
    }

    #[test]
    fn empty_and_zero_capacity() {
        assert!(weighted_max_min_shares(10.0, &[], &[]).is_empty());
        let s = weighted_max_min_shares(0.0, &[5.0, 5.0], &[1.0, 1.0]);
        assert_eq!(s, vec![0.0, 0.0]);
    }

    #[test]
    fn prop_feasible_capped_and_work_conserving() {
        check("max-min shares feasible", Config::default(), |rng| {
            let n = rng.int_in(1, 10) as usize;
            let capacity = rng.range(0.0, 50.0);
            let demands: Vec<f64> = (0..n).map(|_| rng.range(0.0, 20.0)).collect();
            let weights: Vec<f64> = (0..n).map(|_| rng.range(0.1, 5.0)).collect();
            let shares = weighted_max_min_shares(capacity, &demands, &weights);
            let total: f64 = shares.iter().sum();
            let demand_total: f64 = demands.iter().sum();
            if total > capacity + 1e-6 {
                return Err(format!("overcommitted: {total} > {capacity}"));
            }
            let expected = demand_total.min(capacity);
            if (total - expected).abs() > 1e-6 {
                return Err(format!(
                    "not work-conserving: allocated {total}, expected {expected}"
                ));
            }
            for (i, (&s, &d)) in shares.iter().zip(&demands).enumerate() {
                if s > d + 1e-9 {
                    return Err(format!("stream {i} got {s} > demand {d}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_bottleneck_streams_have_equal_normalised_shares() {
        check("max-min bottleneck fairness", Config::default(), |rng| {
            let n = rng.int_in(2, 8) as usize;
            let capacity = rng.range(1.0, 20.0);
            let demands: Vec<f64> = (0..n).map(|_| rng.range(0.5, 15.0)).collect();
            let weights: Vec<f64> = (0..n).map(|_| rng.range(0.2, 4.0)).collect();
            let shares = weighted_max_min_shares(capacity, &demands, &weights);
            let unsatisfied: Vec<usize> = (0..n)
                .filter(|&i| shares[i] < demands[i] - 1e-6)
                .collect();
            // All unsatisfied streams share one normalised water level...
            for w in unsatisfied.windows(2) {
                let a = shares[w[0]] / weights[w[0]];
                let b = shares[w[1]] / weights[w[1]];
                if (a - b).abs() > 1e-6 {
                    return Err(format!("unequal levels {a} vs {b}"));
                }
            }
            // ...and no satisfied stream sits above it.
            if let Some(&u) = unsatisfied.first() {
                let level = shares[u] / weights[u];
                for i in 0..n {
                    if shares[i] >= demands[i] - 1e-6
                        && shares[i] / weights[i] > level + 1e-6
                    {
                        return Err(format!(
                            "satisfied stream {i} above the water level"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn decide_admits_with_headroom() {
        let p = AdmissionPolicy::default();
        match p.decide(20.0, &[], (5.0, 1.0)) {
            Decision::Admit { share } => assert!(share >= 5.0 - 1e-9),
            other => panic!("expected admit, got {other:?}"),
        }
    }

    #[test]
    fn decide_degrades_under_contention() {
        let p = AdmissionPolicy::default();
        // Capacity 9.5, three equal streams of 5: share ≈ 3.17 each.
        let d = p.decide(10.0, &[(5.0, 1.0), (5.0, 1.0)], (5.0, 1.0));
        match d {
            Decision::Degrade { stride, share } => {
                assert_eq!(stride, 2, "{d:?}");
                assert!(share > 3.0 && share < 3.3, "{d:?}");
            }
            other => panic!("expected degrade, got {other:?}"),
        }
        assert!(d.is_admitted());
        assert_eq!(d.stride(), 2);
    }

    #[test]
    fn decide_rejects_below_min_rate() {
        let p = AdmissionPolicy::default();
        let admitted: Vec<(f64, f64)> = (0..9).map(|_| (5.0, 1.0)).collect();
        // Capacity 9.5 over 10 claimants: share 0.95 < min_rate 1.0.
        let d = p.decide(10.0, &admitted, (5.0, 1.0));
        assert_eq!(d, Decision::Reject);
        assert!(!d.is_admitted());
    }

    #[test]
    fn rebalance_throttles_running_streams_but_never_evicts() {
        let p = AdmissionPolicy::default();
        // Capacity 9.5: four 5-FPS members -> everyone levels to 2.375.
        let members = [(5.0, 1.0); 4];
        let levels = p.rebalance(10.0, &members);
        assert_eq!(levels.len(), 4);
        for d in &levels[..3] {
            match d {
                Decision::Degrade { stride, share } => {
                    assert_eq!(*stride, 3, "{d:?}");
                    assert!((share - 2.375).abs() < 1e-9);
                }
                other => panic!("running stream evicted or admitted: {other:?}"),
            }
        }
        // Admitted effective load fits the capacity.
        let effective: f64 = members
            .iter()
            .zip(&levels)
            .filter(|(_, d)| d.is_admitted())
            .map(|(&(demand, _), d)| demand / d.stride() as f64)
            .sum();
        assert!(effective <= 9.5 + 1e-9, "effective {effective}");
    }

    #[test]
    fn rebalance_rejected_candidate_leaves_survivors_at_old_level() {
        let p = AdmissionPolicy::default();
        // Nine members exhaust capacity 9.5 at share ~1.06 each; the
        // tenth pushes shares to 0.95 < min_rate and is rejected, so the
        // nine keep the 9-way level.
        let mut members = vec![(5.0, 1.0); 9];
        members.push((5.0, 1.0));
        let levels = p.rebalance(10.0, &members);
        assert_eq!(levels[9], Decision::Reject);
        for d in &levels[..9] {
            match d {
                Decision::Degrade { share, .. } => {
                    assert!((share - 9.5 / 9.0).abs() < 1e-9, "{d:?}")
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn hold_admits_full_rate_but_still_rejects_starved_candidates() {
        let p = AdmissionPolicy {
            hold: true,
            ..AdmissionPolicy::default()
        };
        // Contention that would normally stride to 1/2: held at full
        // rate instead.
        let d = p.decide(10.0, &[(5.0, 1.0), (5.0, 1.0)], (5.0, 1.0));
        assert!(matches!(d, Decision::Admit { .. }), "{d:?}");
        // Running streams are held too.
        for d in p.rebalance(10.0, &[(5.0, 1.0); 4]) {
            assert!(matches!(d, Decision::Admit { .. }), "{d:?}");
        }
        // The reject path is untouched: a candidate whose share falls
        // below min_rate still never joins mid-burst.
        let admitted: Vec<(f64, f64)> = (0..9).map(|_| (5.0, 1.0)).collect();
        assert_eq!(p.decide(10.0, &admitted, (5.0, 1.0)), Decision::Reject);
        // Disarming restores the reactive stride immediately.
        let p = AdmissionPolicy { hold: false, ..p };
        let d = p.decide(10.0, &[(5.0, 1.0), (5.0, 1.0)], (5.0, 1.0));
        assert!(matches!(d, Decision::Degrade { stride: 2, .. }), "{d:?}");
    }

    #[test]
    fn admit_all_never_gates() {
        let p = AdmissionPolicy::admit_all();
        let d = p.decide(0.0, &[(100.0, 1.0)], (50.0, 1.0));
        assert!(matches!(d, Decision::Admit { .. }));
    }

    #[test]
    fn decision_labels() {
        assert_eq!(Decision::Admit { share: 5.0 }.label(), "admit");
        assert_eq!(Decision::Degrade { stride: 3, share: 1.0 }.label(), "degrade(1/3)");
        assert_eq!(
            Decision::SwapModel { rung: 1, stride: 1, share: 2.0 }.label(),
            "swap(rung 1)"
        );
        assert_eq!(
            Decision::SwapModel { rung: 2, stride: 4, share: 0.5 }.label(),
            "swap(rung 2, 1/4)"
        );
        assert_eq!(Decision::Reject.label(), "reject");
    }

    // ---- model-swap degrade mode (quality-aware admission) -------------

    fn ladder_policy() -> AdmissionPolicy {
        AdmissionPolicy::with_ladder(vec![1.0, 2.6, 3.2])
    }

    #[test]
    fn model_swap_picks_cheapest_sufficient_rung() {
        let p = ladder_policy();
        // Pool 5 -> capacity 4.75, two 5-FPS claimants: share 2.375 each.
        // Rung 1 fits (5/2.6 ≈ 1.92 ≤ 2.375) with no residual stride.
        let d = p.decide(5.0, &[(5.0, 1.0)], (5.0, 1.0));
        match d {
            Decision::SwapModel { rung, stride, .. } => {
                assert_eq!(rung, 1, "{d:?}");
                assert_eq!(stride, 1, "{d:?}");
            }
            other => panic!("expected swap, got {other:?}"),
        }
        assert_eq!(d.rung(), 1);
        assert_eq!(d.stride(), 1);
    }

    #[test]
    fn model_swap_falls_back_to_residual_stride() {
        let p = ladder_policy();
        // Pool 3 -> capacity 2.85, two 5-FPS claimants: share 1.425.
        // Even the fastest rung needs 5/3.2 = 1.5625 > 1.425, so the
        // decision lands on the deepest rung with a residual stride of
        // ceil(1.5625 / 1.425) = 2.
        let d = p.decide(3.0, &[(5.0, 1.0)], (5.0, 1.0));
        match d {
            Decision::SwapModel { rung, stride, .. } => {
                assert_eq!(rung, 2, "{d:?}");
                assert_eq!(stride, 2, "{d:?}");
            }
            other => panic!("expected deepest-rung swap, got {other:?}"),
        }
    }

    #[test]
    fn model_swap_still_admits_when_share_covers_demand() {
        let p = ladder_policy();
        let d = p.decide(20.0, &[], (5.0, 1.0));
        assert!(matches!(d, Decision::Admit { .. }), "{d:?}");
        // And still rejects below min_rate.
        let admitted: Vec<(f64, f64)> = (0..9).map(|_| (5.0, 1.0)).collect();
        assert_eq!(p.decide(10.0, &admitted, (5.0, 1.0)), Decision::Reject);
    }

    #[test]
    fn degenerate_ladder_degrades_by_stride() {
        let p = AdmissionPolicy::with_ladder(vec![1.0]);
        let d = p.decide(10.0, &[(5.0, 1.0), (5.0, 1.0)], (5.0, 1.0));
        assert!(matches!(d, Decision::Degrade { stride: 2, .. }), "{d:?}");
    }

    #[test]
    fn rung_speedup_lookup_clamps() {
        let p = ladder_policy();
        assert_eq!(p.rung_speedup(0), 1.0);
        assert_eq!(p.rung_speedup(1), 2.6);
        assert_eq!(p.rung_speedup(9), 3.2); // clamp to fastest
        assert_eq!(p.max_rung(), 2);
        let s = AdmissionPolicy::default();
        assert_eq!(s.rung_speedup(3), 1.0);
        assert_eq!(s.max_rung(), 0);
    }

    #[test]
    fn decision_at_rung_override_mapping() {
        let p = ladder_policy();
        // Rung 0 with enough share: plain admit; short share: stride.
        assert!(matches!(
            p.decision_at_rung(5.0, 6.0, 0),
            Decision::Admit { .. }
        ));
        assert!(matches!(
            p.decision_at_rung(5.0, 2.0, 0),
            Decision::Degrade { stride: 3, .. }
        ));
        // Rung 1 covers demand 5 with share 2: 5/2.6 < 2 -> stride 1.
        assert!(matches!(
            p.decision_at_rung(5.0, 2.0, 1),
            Decision::SwapModel { rung: 1, stride: 1, .. }
        ));
        // Rung 2 with a starved share still carries a residual stride.
        assert!(matches!(
            p.decision_at_rung(5.0, 0.5, 2),
            Decision::SwapModel { rung: 2, stride: 4, .. }
        ));
        // Out-of-range rungs clamp to the deepest real rung — the
        // decision never records a rung the ladder cannot serve.
        assert!(matches!(
            p.decision_at_rung(5.0, 2.0, 9),
            Decision::SwapModel { rung: 2, .. }
        ));
        // With no ladder at all, any rung request collapses to rung 0.
        let stride_only = AdmissionPolicy::default();
        assert!(matches!(
            stride_only.decision_at_rung(5.0, 6.0, 3),
            Decision::Admit { .. }
        ));
    }

    // ---- water-filling edge cases --------------------------------------

    #[test]
    #[should_panic(expected = "strictly positive")]
    fn zero_weight_stream_is_rejected_by_contract() {
        // Weights must be strictly positive: a zero weight would divide
        // the water level by zero. The contract is an assert, not a NaN.
        weighted_max_min_shares(10.0, &[5.0, 5.0], &[1.0, 0.0]);
    }

    #[test]
    fn demand_exactly_at_capacity_is_fully_admitted() {
        // Σ demand == capacity exactly: everyone gets exactly their
        // demand (no spurious degrade from float drift).
        let s = weighted_max_min_shares(10.0, &[4.0, 6.0], &[1.0, 2.0]);
        assert_eq!(s, vec![4.0, 6.0]);
        let p = AdmissionPolicy {
            target_utilization: 1.0,
            ..AdmissionPolicy::default()
        };
        let levels = p.rebalance(10.0, &[(4.0, 1.0), (6.0, 2.0)]);
        for d in &levels {
            assert!(matches!(d, Decision::Admit { .. }), "{d:?}");
        }
        // One epsilon over capacity degrades rather than overcommitting.
        let levels = p.rebalance(10.0, &[(4.0, 1.0), (6.0 + 1e-3, 1.0)]);
        let effective: f64 = [(4.0, &levels[0]), (6.0 + 1e-3, &levels[1])]
            .iter()
            .map(|(d, l)| d / l.stride() as f64)
            .sum();
        assert!(effective <= 10.0 + 1e-9, "effective {effective}");
    }

    #[test]
    fn zero_capacity_relevel_throttles_everyone_without_panic() {
        // A single-device pool losing its only device re-levels against
        // capacity 0: running streams are never evicted, but their
        // strides explode so the admitted effective load goes to ~0.
        let p = AdmissionPolicy::default();
        let levels = p.relevel(0.0, &[(5.0, 1.0), (2.0, 3.0)]);
        for (d, &(demand, _)) in levels.iter().zip(&[(5.0, 1.0), (2.0, 3.0)]) {
            match d {
                Decision::Degrade { stride, share } => {
                    assert_eq!(*share, 0.0);
                    assert!(*stride >= 1_000_000, "stride {stride}");
                    assert!(demand / *stride as f64 < 1e-3);
                }
                other => panic!("expected throttle-to-zero, got {other:?}"),
            }
        }
    }
}
