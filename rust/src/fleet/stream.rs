//! Per-stream state: a paced frame source, its bounded freshness window,
//! its own sequence [`Synchronizer`], and the accumulators that become a
//! per-stream [`crate::coordinator::RunMetrics`] at report time.
//!
//! A stream inside a fleet is exactly the single-stream pipeline's
//! source-side state, replicated: frames arrive at the stream's own λ,
//! the window evicts the oldest unclaimed frame on overflow (the paper's
//! random frame dropping, now per stream), and the synchronizer restores
//! temporal order per stream regardless of which pool device served each
//! frame.

use crate::coordinator::source::FrameWindow;
use crate::coordinator::sync::{Fate, Synchronizer};
use crate::fleet::admission::Decision;
use crate::types::{FrameId, Seconds};
use crate::util::stats::Percentiles;

/// Stream identifier within one fleet run (index into the registry).
pub type StreamId = usize;

/// A periodic rate shape over a stream's base λ: a piecewise-constant
/// multiplier cycling every `period` seconds (the diurnal pattern the
/// forecast layer learns). Bucket `i` of `mults` covers fleet times
/// `[i·period/len, (i+1)·period/len)` within each cycle.
#[derive(Debug, Clone, PartialEq)]
pub struct RateProfile {
    /// Cycle length in seconds (> 0).
    pub period: f64,
    /// Per-bucket rate multipliers (non-empty, each > 0).
    pub mults: Vec<f64>,
}

impl RateProfile {
    pub fn new(period: f64, mults: Vec<f64>) -> RateProfile {
        assert!(
            period.is_finite() && period > 0.0,
            "rate profile period must be positive"
        );
        assert!(!mults.is_empty(), "rate profile needs at least one bucket");
        assert!(
            mults.iter().all(|&m| m.is_finite() && m > 0.0),
            "rate profile multipliers must be positive"
        );
        RateProfile { period, mults }
    }

    /// Multiplier in effect at fleet time `t` (periodic; negative times
    /// wrap like any other).
    pub fn multiplier_at(&self, t: f64) -> f64 {
        let phase = t.rem_euclid(self.period) / self.period;
        let idx = ((phase * self.mults.len() as f64) as usize).min(self.mults.len() - 1);
        self.mults[idx]
    }
}

/// Static description of one stream joining the fleet.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamSpec {
    pub name: String,
    /// Input rate λₛ (frames/second).
    pub fps: f64,
    pub num_frames: u64,
    /// Fairness weight: the stream's claim on pool throughput is
    /// proportional to this, both in admission shares and in dispatch.
    pub weight: f64,
    /// Freshness window (≥ 1): max unclaimed frames held before the
    /// oldest is dropped.
    pub window: usize,
    /// Optional periodic rate shape: the instantaneous offered rate is
    /// `fps × profile.multiplier_at(t)`. `None` means flat λ (every
    /// pre-profile behaviour is unchanged).
    pub profile: Option<RateProfile>,
}

impl StreamSpec {
    pub fn new(name: &str, fps: f64, num_frames: u64) -> StreamSpec {
        assert!(fps > 0.0, "stream fps must be positive");
        StreamSpec {
            name: name.to_string(),
            fps,
            num_frames,
            weight: 1.0,
            window: 4,
            profile: None,
        }
    }

    pub fn with_weight(mut self, weight: f64) -> StreamSpec {
        assert!(weight > 0.0, "stream weight must be positive");
        self.weight = weight;
        self
    }

    pub fn with_window(mut self, window: usize) -> StreamSpec {
        self.window = window.max(1);
        self
    }

    pub fn with_profile(mut self, profile: RateProfile) -> StreamSpec {
        self.profile = Some(profile);
        self
    }

    /// Nominal stream duration in seconds.
    pub fn duration(&self) -> Seconds {
        self.num_frames as f64 / self.fps
    }

    /// Offered load (what admission accounts the stream at).
    pub fn demand(&self) -> f64 {
        self.fps
    }

    /// Instantaneous offered rate at fleet time `t` (the profiled λ;
    /// equals `fps` for flat streams).
    pub fn rate_at(&self, t: Seconds) -> f64 {
        match &self.profile {
            Some(p) => self.fps * p.multiplier_at(t),
            None => self.fps,
        }
    }

    /// Offered load at fleet time `t` (what time-aware admission and
    /// gossip digests account the stream at).
    pub fn demand_at(&self, t: Seconds) -> f64 {
        self.rate_at(t)
    }
}

/// Live per-stream state inside a running fleet.
#[derive(Debug)]
pub struct StreamState {
    pub id: StreamId,
    pub spec: StreamSpec,
    pub decision: Decision,
    /// Fleet time at which the stream attached; frame `f` is captured at
    /// `attached_at + f / fps`.
    pub attached_at: Seconds,
    pub detached: bool,
    pub window: FrameWindow,
    pub sync: Synchronizer,
    pub latency: Percentiles,
    /// Frames that have arrived so far — cross-checked against the
    /// emitted record log at report time (conservation invariant).
    pub arrived: u64,
    /// Weighted-fair-queueing virtual time: bumped by `1/weight` per
    /// dispatched frame; the dispatcher serves the backlogged stream with
    /// the smallest value.
    pub vtime: f64,
    /// Busy seconds on each pool device attributable to this stream.
    pub device_busy: Vec<f64>,
    /// Frames of this stream processed by each pool device.
    pub device_frames: Vec<u64>,
    /// Latest fate-resolution time (stream-local makespan tracking).
    pub last_resolution: Seconds,
    /// Ladder-rung timeline: `(fleet time, rung)` appended whenever the
    /// stream's decision moves to a different model rung (0 = full
    /// quality). Lets reports attribute per-frame quality to the model
    /// variant that was live at capture time.
    pub rung_log: Vec<(Seconds, usize)>,
}

impl StreamState {
    pub fn new(
        id: StreamId,
        spec: StreamSpec,
        decision: Decision,
        attached_at: Seconds,
        num_devices: usize,
    ) -> StreamState {
        let window = FrameWindow::new(spec.window.max(1));
        StreamState {
            id,
            spec,
            decision,
            attached_at,
            detached: false,
            window,
            sync: Synchronizer::new(),
            latency: Percentiles::new(),
            arrived: 0,
            vtime: 0.0,
            device_busy: vec![0.0; num_devices],
            device_frames: vec![0; num_devices],
            last_resolution: attached_at,
            rung_log: vec![(attached_at, decision.rung())],
        }
    }

    /// Install a new admission decision at fleet time `now`, recording a
    /// rung transition when the model variant changed.
    pub fn set_decision(&mut self, decision: Decision, now: Seconds) {
        let rung = decision.rung();
        if self.rung_log.last().map(|&(_, r)| r) != Some(rung) {
            self.rung_log.push((now, rung));
        }
        self.decision = decision;
    }

    /// Rung live at fleet time `t` (0 before the stream attached).
    pub fn rung_at(&self, t: Seconds) -> usize {
        crate::util::stats::timeline_at(&self.rung_log, t).unwrap_or(0)
    }

    /// Capture timestamp of frame `fid` in fleet time.
    pub fn capture_ts(&self, fid: FrameId) -> Seconds {
        self.attached_at + fid as f64 / self.spec.fps
    }

    /// Does the admission decision keep this frame? (Degraded streams
    /// keep every `stride`-th frame.)
    pub fn keeps(&self, fid: FrameId) -> bool {
        fid % self.decision.stride() == 0
    }

    /// Eligible for dispatch right now.
    pub fn backlogged(&self) -> bool {
        self.decision.is_admitted() && !self.detached && !self.window.is_empty()
    }

    /// Report frame `fid`'s fate at fleet time `now`, feeding emitted
    /// records' output latencies into the stream's distribution. Returns
    /// how many records became emittable (they are the tail of
    /// `self.sync.emitted()`), so engines can feed them to observers.
    pub fn resolve(&mut self, fid: FrameId, fate: Fate, now: Seconds) -> usize {
        let base = self.attached_at;
        let fps = self.spec.fps;
        let out = self.sync.resolve(fid, fate, now, |f| base + f as f64 / fps);
        let n = out.len();
        for r in out {
            self.latency.push((r.emit_ts - r.capture_ts).max(0.0));
        }
        if now > self.last_resolution {
            self.last_resolution = now;
        }
        n
    }

    /// Grow per-device accumulators after a device attach.
    pub fn ensure_devices(&mut self, num_devices: usize) {
        while self.device_busy.len() < num_devices {
            self.device_busy.push(0.0);
            self.device_frames.push(0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sync::Fate;
    use crate::fleet::admission::Decision;

    fn state(decision: Decision) -> StreamState {
        StreamState::new(0, StreamSpec::new("s", 10.0, 100), decision, 2.0, 3)
    }

    #[test]
    fn rate_profile_cycles_and_flat_streams_are_unchanged() {
        let flat = StreamSpec::new("flat", 10.0, 100);
        assert_eq!(flat.rate_at(0.0), 10.0);
        assert_eq!(flat.rate_at(1e6), 10.0);
        assert_eq!(flat.demand_at(3.0), flat.demand());

        // 40-second cycle, four 10-second buckets: night/morning/peak/evening.
        let p = RateProfile::new(40.0, vec![0.5, 1.0, 2.0, 1.0]);
        let s = StreamSpec::new("diurnal", 10.0, 100).with_profile(p);
        assert!((s.rate_at(0.0) - 5.0).abs() < 1e-12);
        assert!((s.rate_at(12.0) - 10.0).abs() < 1e-12);
        assert!((s.rate_at(25.0) - 20.0).abs() < 1e-12);
        assert!((s.rate_at(39.9) - 10.0).abs() < 1e-12);
        // Periodic: one full cycle later the same bucket applies.
        assert!((s.rate_at(65.0) - s.rate_at(25.0)).abs() < 1e-12);
        // Base demand (admission's static view) stays the declared fps.
        assert_eq!(s.demand(), 10.0);
    }

    #[test]
    fn capture_ts_offsets_by_attach_time() {
        let s = state(Decision::Admit { share: 10.0 });
        assert!((s.capture_ts(0) - 2.0).abs() < 1e-12);
        assert!((s.capture_ts(5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn degrade_stride_keeps_every_kth_frame() {
        let s = state(Decision::Degrade { stride: 3, share: 3.0 });
        let kept: Vec<u64> = (0..10).filter(|&f| s.keeps(f)).collect();
        assert_eq!(kept, vec![0, 3, 6, 9]);
        let full = state(Decision::Admit { share: 10.0 });
        assert!((0..10).all(|f| full.keeps(f)));
    }

    #[test]
    fn resolve_tracks_latency_and_time() {
        let mut s = state(Decision::Admit { share: 10.0 });
        s.resolve(0, Fate::Processed { detections: vec![], device: 1 }, 2.4);
        // capture 2.0, emit 2.4 -> latency 0.4
        assert_eq!(s.latency.len(), 1);
        assert!((s.latency.p50() - 0.4).abs() < 1e-9);
        assert!((s.last_resolution - 2.4).abs() < 1e-12);
    }

    #[test]
    fn backlogged_requires_admission_and_frames() {
        let mut s = state(Decision::Admit { share: 10.0 });
        assert!(!s.backlogged());
        s.window.arrive(0);
        assert!(s.backlogged());
        s.detached = true;
        assert!(!s.backlogged());

        let mut r = state(Decision::Reject);
        r.window.arrive(0);
        assert!(!r.backlogged());
    }

    #[test]
    fn rung_log_tracks_decision_transitions() {
        let mut s = state(Decision::Admit { share: 10.0 });
        assert_eq!(s.rung_log, vec![(2.0, 0)]);
        // Same-rung decision changes do not spam the log.
        s.set_decision(Decision::Degrade { stride: 2, share: 5.0 }, 3.0);
        assert_eq!(s.rung_log.len(), 1);
        s.set_decision(Decision::SwapModel { rung: 2, stride: 1, share: 4.0 }, 4.0);
        s.set_decision(Decision::SwapModel { rung: 2, stride: 2, share: 3.0 }, 5.0);
        s.set_decision(Decision::Admit { share: 10.0 }, 6.0);
        assert_eq!(s.rung_log, vec![(2.0, 0), (4.0, 2), (6.0, 0)]);
        assert_eq!(s.rung_at(1.0), 0);
        assert_eq!(s.rung_at(4.5), 2);
        assert_eq!(s.rung_at(9.0), 0);
    }

    #[test]
    fn resolve_reports_emitted_count() {
        let mut s = state(Decision::Admit { share: 10.0 });
        // Frame 1 resolves first: held by the synchronizer.
        assert_eq!(
            s.resolve(1, Fate::Processed { detections: vec![], device: 0 }, 2.3),
            0
        );
        // Frame 0 unblocks both.
        assert_eq!(s.resolve(0, Fate::Dropped, 2.4), 2);
        assert_eq!(s.sync.emitted().len(), 2);
    }

    #[test]
    fn ensure_devices_grows_accumulators() {
        let mut s = state(Decision::Admit { share: 10.0 });
        assert_eq!(s.device_busy.len(), 3);
        s.ensure_devices(5);
        assert_eq!(s.device_busy.len(), 5);
        assert_eq!(s.device_frames.len(), 5);
        s.ensure_devices(2); // never shrinks
        assert_eq!(s.device_busy.len(), 5);
    }
}
