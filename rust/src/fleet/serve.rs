//! Wall-clock fleet serving: many paced streams, one shared worker pool,
//! real detectors — the multi-stream generalisation of
//! [`crate::server::serve`], built from the same ingredients (bounded
//! windows under a `Mutex` + `Condvar`, a collector channel, per-stream
//! sequence synchronizers at assembly time).
//!
//! Topology (one process):
//!
//! ```text
//!  ingest s0 (paces λ₀) ─┐
//!  ingest s1 (paces λ₁) ─┼─► per-stream bounded windows ──┐
//!  ...                   │     (weighted-fair pick)        │
//!                        │              worker 0..n-1 ─────┴─► detect
//!                        └── evictions ──► collector ◄── fates ┘
//!                                              │ per-stream Synchronizer
//!                                              ▼ FleetReport
//! ```
//!
//! Admission decisions are taken up front from the configured nominal
//! device rates (wall-clock capacity is whatever the detectors actually
//! deliver; the nominal rates only gate admission). Rejected streams are
//! not ingested at all — their records are synthesised as dropped.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Barrier, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::coordinator::source::FrameWindow;
use crate::coordinator::sync::{Fate, Synchronizer};
use crate::detector::Detector;
use crate::device::DeviceKind;
use crate::fleet::admission::AdmissionPolicy;
use crate::fleet::metrics::{finish_stream, FleetReport, StreamAccum};
use crate::fleet::stream::StreamSpec;
use crate::gate::{GateConfig, GatePolicy, GateVerdict, MotionModel};
use crate::telemetry::{record_traces, FrameTrace, Registry, RunTelemetry, TraceOutcome};
use crate::types::{Detection, FrameId};
use crate::util::stats::Percentiles;
use crate::video::Clip;

/// Wall-clock fleet configuration.
#[derive(Debug, Clone)]
pub struct FleetServeConfig {
    pub admission: AdmissionPolicy,
    /// Nominal service rates (FPS) of the `n` workers; the vector length
    /// sets the worker count and its sum is the admission capacity Σμᵢ.
    pub device_rates: Vec<f64>,
    /// Pace each stream at its λ (true) or flood (false).
    pub paced: bool,
    /// Per-frame motion gate ([`crate::gate`]); `None` detects every
    /// kept frame. The wall-clock path gates *skips only* — workers are
    /// rung-agnostic, so pressure down-runging stays a virtual-time
    /// engine feature.
    pub gate: Option<GateConfig>,
}

struct Shared {
    state: Mutex<State>,
    cond: Condvar,
}

struct State {
    /// Per-stream bounded freshness windows (indexed by stream id) —
    /// the same `FrameWindow` the virtual-time engine uses.
    queues: Vec<FrameWindow>,
    vtime: Vec<f64>,
    weights: Vec<f64>,
    /// Ingest threads still running; workers exit once this hits zero
    /// and every queue is empty.
    open_streams: usize,
}

/// Ingest-side trace annotation: when the frame cleared (or failed)
/// admission/gate, and why it dropped if it did. Worker-side times come
/// from the fate messages, so the hot detect loop is untouched.
#[derive(Debug, Clone, Copy)]
struct ServeAnn {
    admit: f64,
    dropped: Option<TraceOutcome>,
}

enum Msg {
    Processed {
        sid: usize,
        fid: FrameId,
        device: usize,
        detections: Vec<Detection>,
        at: f64,
        service: f64,
    },
    Dropped {
        sid: usize,
        fid: FrameId,
        at: f64,
    },
}

/// Serve `streams` (clip + spec pairs; stream `s` plays
/// `min(spec.num_frames, clip.len())` frames at `spec.fps`) against a
/// pool of `config.device_rates.len()` workers. `factory(worker)` builds
/// each worker's thread-local detector.
pub fn serve_fleet<F>(
    streams: &[(&Clip, StreamSpec)],
    config: &FleetServeConfig,
    factory: F,
) -> Result<FleetReport>
where
    F: Fn(usize) -> Result<Box<dyn Detector>> + Send + Sync,
{
    serve_fleet_logged(streams, config, factory).map(|(report, _)| report)
}

/// [`serve_fleet`] plus the control-plane wire log: the up-front
/// admission decisions, one [`crate::control::WireEvent`] per stream in
/// attach order — the wall-clock engine's slice of the serialisable
/// control plane (its membership is fixed per run, so decisions are the
/// control traffic it emits).
pub fn serve_fleet_logged<F>(
    streams: &[(&Clip, StreamSpec)],
    config: &FleetServeConfig,
    factory: F,
) -> Result<(FleetReport, crate::control::EventLog)>
where
    F: Fn(usize) -> Result<Box<dyn Detector>> + Send + Sync,
{
    serve_fleet_inner(streams, config, factory, false).map(|(report, log, _)| (report, log))
}

/// [`serve_fleet_logged`] plus per-frame span traces and a metrics
/// registry ([`crate::telemetry`]): capture/admit stamps from the ingest
/// clocks, detect start/end from the fate messages, deliver from the
/// synchronizer — wall-clock seconds since run start throughout. The
/// untraced entry points share this implementation and pay nothing.
pub fn serve_fleet_traced<F>(
    streams: &[(&Clip, StreamSpec)],
    config: &FleetServeConfig,
    factory: F,
) -> Result<(FleetReport, crate::control::EventLog, RunTelemetry)>
where
    F: Fn(usize) -> Result<Box<dyn Detector>> + Send + Sync,
{
    serve_fleet_inner(streams, config, factory, true)
        .map(|(report, log, tel)| (report, log, tel.expect("traced run returns telemetry")))
}

fn serve_fleet_inner<F>(
    streams: &[(&Clip, StreamSpec)],
    config: &FleetServeConfig,
    factory: F,
    traced: bool,
) -> Result<(FleetReport, crate::control::EventLog, Option<RunTelemetry>)>
where
    F: Fn(usize) -> Result<Box<dyn Detector>> + Send + Sync,
{
    let n_workers = config.device_rates.len().max(1);
    let pool_rate: f64 = config.device_rates.iter().sum();
    let n_streams = streams.len();

    // Admission up front, in stream order, re-levelling earlier streams'
    // shares on each attach exactly as the registry does. Model-swap
    // degradation is coerced to stride here: the wall-clock workers are
    // rung-agnostic (one detector per worker), so a `SwapModel` decision
    // would promise a speedup the pool cannot deliver and overcommit it.
    // Rung-aware wall-clock control lives in
    // `crate::autoscale::runner::run_autoscale_serve`, which swaps the
    // detectors themselves between epochs.
    let admission = crate::fleet::admission::AdmissionPolicy {
        degrade: crate::fleet::admission::DegradeMode::Stride,
        ..config.admission.clone()
    };
    let mut decisions: Vec<crate::fleet::admission::Decision> = Vec::with_capacity(n_streams);
    {
        let mut active: Vec<usize> = Vec::new();
        for (i, (_, spec)) in streams.iter().enumerate() {
            let mut members: Vec<(f64, f64)> = active
                .iter()
                .map(|&j| (streams[j].1.demand(), streams[j].1.weight))
                .collect();
            members.push((spec.demand(), spec.weight));
            let levels = admission.rebalance(pool_rate, &members);
            for (k, &j) in active.iter().enumerate() {
                decisions[j] = levels[k];
            }
            let d = levels[levels.len() - 1];
            if d.is_admitted() {
                active.push(i);
            }
            decisions.push(d);
        }
    }

    // The final (post-re-levelling) admission outcomes, as wire events —
    // the run's serialisable control log.
    let mut wire_log = crate::control::EventLog::new();
    for (i, d) in decisions.iter().enumerate() {
        wire_log.push(crate::control::WireEvent::decision(0.0, i, *d));
    }

    let frame_counts: Vec<u64> = streams
        .iter()
        .map(|(clip, spec)| spec.num_frames.min(clip.len() as u64))
        .collect();

    let ingest_ids: Vec<usize> = (0..n_streams)
        .filter(|&s| decisions[s].is_admitted() && frame_counts[s] > 0)
        .collect();

    let shared = Arc::new(Shared {
        state: Mutex::new(State {
            queues: streams
                .iter()
                .map(|(_, s)| FrameWindow::new(s.window.max(1)))
                .collect(),
            vtime: vec![0.0; n_streams],
            weights: streams.iter().map(|(_, s)| s.weight).collect(),
            open_streams: ingest_ids.len(),
        }),
        cond: Condvar::new(),
    });
    let (tx, rx) = mpsc::channel::<Msg>();

    // Gate verdicts collected across ingest threads. Events are stamped
    // at virtual capture time (`fid / fps`) rather than wall-clock so a
    // gated serve run emits the exact same log as the virtual-time
    // engine on the same streams — the EventLog replay contract.
    let gate_events: Arc<Mutex<Vec<crate::control::WireEvent>>> = Arc::new(Mutex::new(Vec::new()));

    // Trace annotations, allocated only for traced runs (ingest threads
    // skip the map entirely otherwise).
    let anns: Option<Arc<Mutex<BTreeMap<(usize, FrameId), ServeAnn>>>> =
        traced.then(|| Arc::new(Mutex::new(BTreeMap::new())));

    // Two barriers: `ready` gates on every worker having built its
    // (possibly expensive) detector; main then stamps t0; `go` releases
    // the paced ingest clocks.
    let total_parties = n_workers + ingest_ids.len() + 1;
    let ready = Arc::new(Barrier::new(total_parties));
    let go = Arc::new(Barrier::new(total_parties));
    let t0_cell = Arc::new(Mutex::new(Instant::now()));
    let failed_workers = Arc::new(AtomicUsize::new(0));

    std::thread::scope(|scope| {
        // Workers.
        for w in 0..n_workers {
            let shared = Arc::clone(&shared);
            let tx = tx.clone();
            let factory = &factory;
            let ready = Arc::clone(&ready);
            let go = Arc::clone(&go);
            let t0_cell = Arc::clone(&t0_cell);
            let failed_workers = Arc::clone(&failed_workers);
            scope.spawn(move || {
                let mut detector = match factory(w) {
                    Ok(d) => Some(d),
                    Err(e) => {
                        eprintln!("[fleet worker {w}] detector construction failed: {e}");
                        failed_workers.fetch_add(1, Ordering::SeqCst);
                        None
                    }
                };
                ready.wait();
                go.wait();
                let Some(mut detector) = detector.take() else { return };
                loop {
                    // Weighted-fair pull: smallest virtual time among
                    // backlogged streams.
                    let job = {
                        let mut st = shared.state.lock().unwrap();
                        loop {
                            let mut pick: Option<usize> = None;
                            for (i, q) in st.queues.iter().enumerate() {
                                if q.is_empty() {
                                    continue;
                                }
                                if pick.map_or(true, |p| st.vtime[i] < st.vtime[p]) {
                                    pick = Some(i);
                                }
                            }
                            if let Some(i) = pick {
                                let fid = st.queues[i].pull().unwrap();
                                let weight = st.weights[i].max(1e-9);
                                st.vtime[i] += 1.0 / weight;
                                break Some((i, fid));
                            }
                            if st.open_streams == 0 {
                                break None;
                            }
                            st = shared.cond.wait(st).unwrap();
                        }
                    };
                    let Some((sid, fid)) = job else { break };
                    let started = Instant::now();
                    let detections = detector.detect(&streams[sid].0.frames[fid as usize]);
                    let service = started.elapsed().as_secs_f64();
                    let at = t0_cell.lock().unwrap().elapsed().as_secs_f64();
                    let _ = tx.send(Msg::Processed {
                        sid,
                        fid,
                        device: w,
                        detections,
                        at,
                        service,
                    });
                }
            });
        }

        // Ingest threads, one per admitted stream.
        for &sid in &ingest_ids {
            let shared = Arc::clone(&shared);
            let tx = tx.clone();
            let ready = Arc::clone(&ready);
            let go = Arc::clone(&go);
            let t0_cell = Arc::clone(&t0_cell);
            let spec = &streams[sid].1;
            let count = frame_counts[sid];
            let stride = decisions[sid].stride();
            let paced = config.paced;
            let gate_cfg = config.gate.clone();
            let gate_events = Arc::clone(&gate_events);
            let anns = anns.clone();
            scope.spawn(move || {
                let mark = |fid: FrameId, at: f64, outcome: Option<TraceOutcome>| {
                    if let Some(a) = anns.as_ref() {
                        let mut m = a.lock().unwrap();
                        let e = m
                            .entry((sid, fid))
                            .or_insert(ServeAnn { admit: at, dropped: None });
                        if outcome.is_some() {
                            e.dropped = outcome;
                        }
                    }
                };
                // Per-stream gate state: the motion model is keyed by the
                // stream *name*, so the same stream gates identically here
                // and in the virtual-time engine.
                let mut gate: Option<(GatePolicy, MotionModel)> = gate_cfg.map(|cfg| {
                    let model = MotionModel::new(&spec.name, cfg.dynamics.clone());
                    (GatePolicy::new(cfg), model)
                });
                ready.wait();
                go.wait();
                let t0 = *t0_cell.lock().unwrap();
                for fid in 0..count {
                    if paced {
                        let target = t0 + Duration::from_secs_f64(fid as f64 / spec.fps);
                        let now = Instant::now();
                        if target > now {
                            std::thread::sleep(target - now);
                        }
                    }
                    let now_s = t0.elapsed().as_secs_f64();
                    if fid % stride != 0 {
                        // Admission-mandated subsampling: dropped on arrival.
                        mark(fid, now_s, Some(TraceOutcome::DroppedStride));
                        let _ = tx.send(Msg::Dropped { sid, fid, at: now_s });
                        continue;
                    }
                    mark(fid, now_s, None);
                    if let Some((policy, model)) = gate.as_mut() {
                        // Skips only on the wall-clock path: workers are
                        // rung-agnostic, so pressure is pinned to 0 and a
                        // down-rung verdict can never fire.
                        let verdict = policy.decide(model.energy(fid), 0.0);
                        if verdict != GateVerdict::Detect {
                            gate_events.lock().unwrap().push(crate::control::WireEvent::gate(
                                fid as f64 / spec.fps,
                                sid,
                                fid,
                                verdict,
                            ));
                        }
                        if !verdict.detects() {
                            mark(fid, now_s, Some(TraceOutcome::DroppedGate));
                            let _ = tx.send(Msg::Dropped { sid, fid, at: now_s });
                            continue;
                        }
                    }
                    let evicted = {
                        let mut st = shared.state.lock().unwrap();
                        st.queues[sid].arrive(fid).evicted
                    };
                    if let Some(old) = evicted {
                        mark(old, now_s, Some(TraceOutcome::DroppedEvicted));
                        let _ = tx.send(Msg::Dropped { sid, fid: old, at: now_s });
                    }
                    shared.cond.notify_one();
                }
                {
                    let mut st = shared.state.lock().unwrap();
                    st.open_streams -= 1;
                }
                // Wake every worker so the exit condition is re-checked.
                shared.cond.notify_all();
            });
        }
        drop(tx);

        ready.wait();
        *t0_cell.lock().unwrap() = Instant::now();
        go.wait();
    });

    let wall = t0_cell.lock().unwrap().elapsed().as_secs_f64();

    // Append the gate verdicts after the admission decisions, ordered by
    // capture time (stream id breaks ties) so the log is deterministic
    // regardless of ingest-thread interleaving.
    {
        let mut gated = std::mem::take(&mut *gate_events.lock().unwrap());
        gated.sort_by(|a, b| {
            let key = |ev: &crate::control::WireEvent| match ev.payload {
                crate::control::WirePayload::Gate { stream, frame, .. } => (stream, frame),
                _ => (usize::MAX, u64::MAX),
            };
            a.at.total_cmp(&b.at).then_with(|| key(a).cmp(&key(b)))
        });
        for ev in gated {
            wire_log.push(ev);
        }
    }

    // With zero live workers, queued frames were never consumed and never
    // resolved, so the "one record per frame" invariant cannot hold —
    // surface that as an error instead of a silently truncated report.
    if failed_workers.load(Ordering::SeqCst) == n_workers && !ingest_ids.is_empty() {
        bail!("all {n_workers} fleet worker detector factories failed; no frames were processed");
    }

    // Assemble: group fates per stream, sort by fate time, synchronize.
    let mut fates: Vec<Vec<(FrameId, f64, Option<(usize, Vec<Detection>, f64)>)>> =
        (0..n_streams).map(|_| Vec::new()).collect();
    let mut device_busy = vec![0.0f64; n_workers];
    let mut device_frames = vec![0u64; n_workers];
    for msg in rx.into_iter() {
        match msg {
            Msg::Processed {
                sid,
                fid,
                device,
                detections,
                at,
                service,
            } => {
                device_busy[device] += service;
                device_frames[device] += 1;
                fates[sid].push((fid, at, Some((device, detections, service))));
            }
            Msg::Dropped { sid, fid, at } => fates[sid].push((fid, at, None)),
        }
    }

    // Snapshot of the ingest-side annotations (threads have joined, so
    // this is the final state). Empty when untraced.
    let anns_map: BTreeMap<(usize, FrameId), ServeAnn> = match &anns {
        Some(a) => a.lock().unwrap().clone(),
        None => BTreeMap::new(),
    };
    let mut all_traces: Vec<FrameTrace> = Vec::new();

    let kinds = vec![DeviceKind::FastCpu; n_workers];
    let mut reports = Vec::with_capacity(n_streams);
    for (sid, mut stream_fates) in fates.into_iter().enumerate() {
        let spec = &streams[sid].1;
        let count = frame_counts[sid];
        let mut sync = Synchronizer::new();
        let mut latency = Percentiles::new();
        let mut s_busy = vec![0.0f64; n_workers];
        let mut s_frames = vec![0u64; n_workers];
        let fps = spec.fps;
        // fid → (device, detect end, service) for traced runs.
        let mut done: BTreeMap<FrameId, (usize, f64, f64)> = BTreeMap::new();

        if decisions[sid].is_admitted() {
            stream_fates.sort_by(|a, b| {
                a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal)
            });
            for (fid, at, outcome) in stream_fates {
                let fate = match outcome {
                    Some((device, detections, service)) => {
                        s_busy[device] += service;
                        s_frames[device] += 1;
                        if traced {
                            done.insert(fid, (device, at, service));
                        }
                        Fate::Processed { detections, device }
                    }
                    None => Fate::Dropped,
                };
                for r in sync.resolve(fid, fate, at, |f| f as f64 / fps) {
                    latency.push((r.emit_ts - r.capture_ts).max(0.0));
                }
            }
        } else {
            // Rejected stream: synthesise the full dropped record log at
            // capture timestamps.
            for fid in 0..count {
                let ts = fid as f64 / fps;
                for r in sync.resolve(fid, Fate::Dropped, ts, |f| f as f64 / fps) {
                    latency.push((r.emit_ts - r.capture_ts).max(0.0));
                }
            }
        }

        if traced {
            for r in sync.emitted() {
                let dropped = r.was_dropped();
                let ann = anns_map.get(&(sid, r.frame_id)).copied();
                let admit = ann.map(|a| a.admit).unwrap_or(r.capture_ts);
                let (detect_start, detect_end, device) = match done.get(&r.frame_id) {
                    // The fate message carries end + service; start is
                    // recovered as `end - service`, clamped so a paced
                    // stream's stage partition stays monotone.
                    Some(&(dev, end, service)) => {
                        (Some((end - service).max(admit)), Some(end), Some(dev))
                    }
                    None => (None, None, None),
                };
                let outcome = if !dropped {
                    TraceOutcome::Delivered
                } else if !decisions[sid].is_admitted() {
                    TraceOutcome::DroppedRejected
                } else {
                    ann.and_then(|a| a.dropped)
                        .unwrap_or(TraceOutcome::DroppedDrained)
                };
                all_traces.push(FrameTrace {
                    stream: sid,
                    frame: r.frame_id,
                    capture: r.capture_ts,
                    admit,
                    detect_start,
                    detect_end,
                    deliver: Some(r.emit_ts),
                    outcome,
                    rung: if dropped { None } else { Some(decisions[sid].rung()) },
                    device,
                });
            }
        }

        let acc = StreamAccum {
            id: sid,
            name: spec.name.clone(),
            weight: spec.weight,
            decision: decisions[sid],
            records: sync.emitted().to_vec(),
            max_reorder_depth: sync.max_pending(),
            latency,
            device_busy: s_busy,
            device_frames: s_frames,
            makespan: wall.max(1e-12),
            stream_duration: count as f64 / fps,
            rung_log: vec![(0.0, decisions[sid].rung())],
        };
        reports.push(finish_stream(acc, &kinds));
    }

    let telemetry = if traced {
        let mut registry = Registry::new();
        record_traces(&mut registry, &all_traces);
        Some(RunTelemetry {
            registry,
            traces: all_traces,
        })
    } else {
        None
    };

    Ok((
        FleetReport {
            streams: reports,
            makespan: wall,
            device_busy,
            device_frames,
            device_labels: (0..n_workers)
                .map(|w| {
                    let nominal = config.device_rates.get(w).copied().unwrap_or(0.0);
                    format!("worker#{w} (nominal {nominal:.1} FPS)")
                })
                .collect(),
        },
        wire_log,
        telemetry,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Frame;
    use crate::video::{generate, presets};

    /// Echoes ground truth after a fixed delay.
    struct EchoDetector {
        delay: Duration,
    }

    impl Detector for EchoDetector {
        fn detect(&mut self, frame: &Frame) -> Vec<Detection> {
            std::thread::sleep(self.delay);
            frame
                .ground_truth
                .iter()
                .map(|gt| Detection {
                    bbox: gt.bbox,
                    class_id: gt.class_id,
                    score: 0.9,
                })
                .collect()
        }

        fn label(&self) -> String {
            "echo".into()
        }
    }

    #[test]
    fn two_streams_share_two_workers_without_drops() {
        // 2 streams × 15 FPS with 5 ms service on 2 workers: capacity
        // ≈ 400 FPS ≫ 30 FPS offered; nothing should drop.
        let clip_a = generate(&presets::tiny_clip(32, 30, 15.0, 1), None);
        let clip_b = generate(&presets::tiny_clip(32, 30, 15.0, 2), None);
        let streams = [
            (&clip_a, StreamSpec::new("a", 15.0, 30).with_window(4)),
            (&clip_b, StreamSpec::new("b", 15.0, 30).with_window(4)),
        ];
        let config = FleetServeConfig {
            admission: AdmissionPolicy::admit_all(),
            device_rates: vec![200.0, 200.0],
            paced: true,
            gate: None,
        };
        let report = serve_fleet(&streams, &config, |_| {
            Ok(Box::new(EchoDetector {
                delay: Duration::from_millis(5),
            }) as Box<dyn Detector>)
        })
        .unwrap();
        assert_eq!(report.streams.len(), 2);
        for s in &report.streams {
            assert_eq!(s.records.len(), 30, "stream {}", s.name);
            assert_eq!(s.metrics.frames_dropped, 0, "stream {}", s.name);
            for (i, r) in s.records.iter().enumerate() {
                assert_eq!(r.frame_id, i as u64);
            }
        }
        assert_eq!(report.total_processed(), 60);
    }

    #[test]
    fn overloaded_pool_drops_but_every_frame_is_recorded() {
        // 2 streams × 50 FPS against one worker with 25 ms service
        // (≈40 FPS capacity): drops are inevitable, records complete.
        let clip_a = generate(&presets::tiny_clip(32, 40, 50.0, 3), None);
        let clip_b = generate(&presets::tiny_clip(32, 40, 50.0, 4), None);
        let streams = [
            (&clip_a, StreamSpec::new("a", 50.0, 40).with_window(2)),
            (&clip_b, StreamSpec::new("b", 50.0, 40).with_window(2)),
        ];
        let config = FleetServeConfig {
            admission: AdmissionPolicy::admit_all(),
            device_rates: vec![40.0],
            paced: true,
            gate: None,
        };
        let report = serve_fleet(&streams, &config, |_| {
            Ok(Box::new(EchoDetector {
                delay: Duration::from_millis(25),
            }) as Box<dyn Detector>)
        })
        .unwrap();
        let total_dropped: u64 = report.streams.iter().map(|s| s.metrics.frames_dropped).sum();
        assert!(total_dropped > 10, "dropped {total_dropped}");
        for s in &report.streams {
            assert_eq!(s.records.len(), 40);
        }
    }

    #[test]
    fn all_factories_failing_is_an_error_not_a_truncated_report() {
        let clip = generate(&presets::tiny_clip(32, 10, 30.0, 7), None);
        let streams = [(&clip, StreamSpec::new("a", 30.0, 10).with_window(2))];
        let config = FleetServeConfig {
            admission: AdmissionPolicy::admit_all(),
            device_rates: vec![40.0, 40.0],
            paced: false,
            gate: None,
        };
        let result = serve_fleet(&streams, &config, |w| {
            Err(anyhow::anyhow!("worker {w}: backend unavailable"))
        });
        let err = result.err().expect("total factory failure must error");
        assert!(err.to_string().contains("factories failed"), "{err}");
    }

    #[test]
    fn ladder_admission_is_coerced_to_stride_on_the_wall_clock_path() {
        // Workers are rung-agnostic, so a ModelSwap policy must degrade
        // by stride here instead of promising a speedup the pool cannot
        // deliver (that would overcommit it ~2.6×).
        let clip = generate(&presets::tiny_clip(32, 30, 30.0, 8), None);
        let streams = [(&clip, StreamSpec::new("a", 30.0, 30).with_window(4))];
        let config = FleetServeConfig {
            admission: AdmissionPolicy::with_ladder(vec![1.0, 2.6, 3.2]),
            device_rates: vec![15.0],
            paced: false,
            gate: None,
        };
        let report = serve_fleet(&streams, &config, |_| {
            Ok(Box::new(EchoDetector {
                delay: Duration::from_millis(1),
            }) as Box<dyn Detector>)
        })
        .unwrap();
        let d = report.streams[0].decision;
        assert!(
            matches!(d, crate::fleet::admission::Decision::Degrade { .. }),
            "expected stride degradation, got {d:?}"
        );
        assert_eq!(d.rung(), 0);
    }

    #[test]
    fn rejected_stream_is_fully_synthesised() {
        // Admission capacity ≈ 1.9 FPS: the 30-FPS streams cannot fit at
        // min_rate 1.0 for stream 1 once stream 0 holds a share.
        let clip_a = generate(&presets::tiny_clip(32, 20, 30.0, 5), None);
        let clip_b = generate(&presets::tiny_clip(32, 20, 30.0, 6), None);
        let streams = [
            (&clip_a, StreamSpec::new("a", 30.0, 20).with_window(2)),
            (&clip_b, StreamSpec::new("b", 30.0, 20).with_window(2)),
        ];
        let config = FleetServeConfig {
            admission: AdmissionPolicy {
                min_rate: 1.5,
                ..AdmissionPolicy::default()
            },
            device_rates: vec![2.0],
            paced: false,
            gate: None,
        };
        let report = serve_fleet(&streams, &config, |_| {
            Ok(Box::new(EchoDetector {
                delay: Duration::from_millis(1),
            }) as Box<dyn Detector>)
        })
        .unwrap();
        let rejected: Vec<_> = report
            .streams
            .iter()
            .filter(|s| !s.decision.is_admitted())
            .collect();
        assert!(!rejected.is_empty(), "expected a rejection");
        for s in rejected {
            assert_eq!(s.records.len(), 20);
            assert!(s.records.iter().all(|r| r.was_dropped()));
        }
    }

    #[test]
    fn logged_serve_emits_one_wire_decision_per_stream() {
        use crate::control::{EventLog, WirePayload};
        let clip_a = generate(&presets::tiny_clip(32, 10, 20.0, 9), None);
        let clip_b = generate(&presets::tiny_clip(32, 10, 20.0, 10), None);
        let streams = [
            (&clip_a, StreamSpec::new("a", 20.0, 10).with_window(4)),
            (&clip_b, StreamSpec::new("b", 20.0, 10).with_window(4)),
        ];
        let config = FleetServeConfig {
            admission: AdmissionPolicy::default(),
            device_rates: vec![100.0],
            paced: false,
            gate: None,
        };
        let (report, log) = serve_fleet_logged(&streams, &config, |_| {
            Ok(Box::new(EchoDetector {
                delay: Duration::from_millis(1),
            }) as Box<dyn Detector>)
        })
        .unwrap();
        assert_eq!(log.len(), 2);
        // The log round-trips through the wire and matches the report's
        // decisions exactly.
        let back = EventLog::decode(&log.encode()).expect("wire round-trip");
        assert_eq!(back, log);
        for (i, ev) in back.events.iter().enumerate() {
            match &ev.payload {
                WirePayload::Decision { stream, decision } => {
                    assert_eq!(*stream, i);
                    assert_eq!(*decision, report.streams[i].decision);
                }
                other => panic!("expected a decision payload, got {other:?}"),
            }
        }
    }

    #[test]
    fn traced_serve_partitions_wall_clock_latency() {
        let clip_a = generate(&presets::tiny_clip(32, 30, 15.0, 12), None);
        let clip_b = generate(&presets::tiny_clip(32, 30, 15.0, 13), None);
        let streams = [
            (&clip_a, StreamSpec::new("a", 15.0, 30).with_window(4)),
            (&clip_b, StreamSpec::new("b", 15.0, 30).with_window(4)),
        ];
        let config = FleetServeConfig {
            admission: AdmissionPolicy::admit_all(),
            device_rates: vec![200.0, 200.0],
            paced: true,
            gate: None,
        };
        let (report, _log, tel) = serve_fleet_traced(&streams, &config, |_| {
            Ok(Box::new(EchoDetector {
                delay: Duration::from_millis(5),
            }) as Box<dyn Detector>)
        })
        .unwrap();
        // One trace per frame; delivered count agrees with the report.
        assert_eq!(tel.traces.len() as u64, report.total_frames());
        let delivered: Vec<_> = tel
            .traces
            .iter()
            .filter(|t| t.outcome == TraceOutcome::Delivered)
            .collect();
        assert_eq!(delivered.len() as u64, report.total_processed());
        // Paced ingest keeps the stamps monotone, so every delivered
        // frame's stage durations partition its e2e latency exactly.
        for t in &delivered {
            assert!(t.admit >= t.capture, "paced admit trails capture");
            let stages = t.stage_seconds().expect("delivered frames have stages");
            let e2e = t.e2e().expect("delivered frames have e2e");
            assert!(
                (stages.iter().sum::<f64>() - e2e).abs() < 1e-9,
                "stages {stages:?} vs e2e {e2e}"
            );
            assert!(t.device.is_some());
        }
        assert_eq!(
            tel.registry.counter_family_total("eva_frames_total"),
            report.total_frames()
        );
    }

    #[test]
    fn gated_serve_skips_quiet_frames_and_logs_verdicts_deterministically() {
        use crate::control::{EventLog, WirePayload};
        use crate::gate::GateVerdict;
        // One quiet stream under the default (lobby-dynamics) gate: frame
        // 0 detects, then the policy settles into skip/skip/refresh-cap
        // triples. 30 frames ⇒ 20 skips + 9 caps, 10 frames detected.
        let clip = generate(&presets::tiny_clip(32, 30, 30.0, 11), None);
        let run = || {
            let streams = [(&clip, StreamSpec::new("lobby", 30.0, 30).with_window(4))];
            let config = FleetServeConfig {
                admission: AdmissionPolicy::admit_all(),
                device_rates: vec![100.0],
                paced: true,
                gate: Some(GateConfig::default()),
            };
            serve_fleet_logged(&streams, &config, |_| {
                Ok(Box::new(EchoDetector {
                    delay: Duration::from_millis(1),
                }) as Box<dyn Detector>)
            })
            .unwrap()
        };
        let (report, log) = run();
        let s = &report.streams[0];
        assert_eq!(s.records.len(), 30);
        assert_eq!(s.metrics.frames_dropped, 20, "gate-skipped frames drop");
        assert_eq!(s.metrics.frames_processed, 10);
        // 1 admission decision + one event per non-Detect verdict.
        assert_eq!(log.len(), 1 + 29);
        let mut skips = 0;
        let mut caps = 0;
        for ev in &log.events[1..] {
            match &ev.payload {
                WirePayload::Gate { stream, verdict, .. } => {
                    assert_eq!(*stream, 0);
                    match verdict {
                        GateVerdict::Skip => skips += 1,
                        GateVerdict::SkipCap => caps += 1,
                        other => panic!("unexpected verdict {other:?}"),
                    }
                }
                other => panic!("expected a gate payload, got {other:?}"),
            }
        }
        assert_eq!((skips, caps), (20, 9));
        // The log survives the wire and a re-run reproduces it verbatim:
        // gate events are stamped at virtual capture time, so wall-clock
        // jitter cannot leak into the replayable record.
        assert_eq!(EventLog::decode(&log.encode()).unwrap(), log);
        let (_, log2) = run();
        assert_eq!(log2, log);
    }
}
