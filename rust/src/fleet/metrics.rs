//! Fleet-level metrics: per-stream reports (wrapping the single-run
//! [`RunMetrics`]) plus aggregates across streams and devices — total
//! detection throughput, drop rates, device utilisation, and Jain's
//! fairness index over per-stream weighted throughput.

use crate::coordinator::metrics::RunMetrics;
use crate::device::energy::EnergyMeter;
use crate::device::DeviceKind;
use crate::fleet::admission::Decision;
use crate::types::{OutputRecord, Seconds};
use crate::util::json::Json;
use crate::util::stats::Percentiles;
use crate::util::table::{f, Table};
use std::collections::BTreeMap;

/// Raw per-stream accumulators handed to [`finish_stream`] by an engine
/// (virtual-time or wall-clock) at the end of a run.
pub struct StreamAccum {
    pub id: usize,
    pub name: String,
    pub weight: f64,
    pub decision: Decision,
    pub records: Vec<OutputRecord>,
    pub latency: Percentiles,
    pub device_busy: Vec<Seconds>,
    pub device_frames: Vec<u64>,
    /// Stream-local elapsed time (attach → last resolution).
    pub makespan: Seconds,
    pub stream_duration: Seconds,
    /// Reorder-buffer high-water mark (`Synchronizer::max_pending`).
    pub max_reorder_depth: usize,
    /// Model-ladder rung timeline `(fleet time, rung)`; `[(t0, 0)]` for
    /// engines without quality-aware admission.
    pub rung_log: Vec<(Seconds, usize)>,
}

/// Final per-stream result.
pub struct StreamReport {
    pub id: usize,
    pub name: String,
    pub weight: f64,
    pub decision: Decision,
    pub records: Vec<OutputRecord>,
    pub metrics: RunMetrics,
    /// Model-ladder rung timeline `(fleet time, rung)`.
    pub rung_log: Vec<(Seconds, usize)>,
}

impl StreamReport {
    /// Rung live at fleet time `t` (0 before the first entry).
    pub fn rung_at(&self, t: Seconds) -> usize {
        crate::util::stats::timeline_at(&self.rung_log, t).unwrap_or(0)
    }
}

/// Convert accumulators into a [`StreamReport`]. `kinds` is the pool's
/// device-kind vector (for per-stream energy attribution).
pub fn finish_stream(acc: StreamAccum, kinds: &[DeviceKind]) -> StreamReport {
    let frames_total = acc.records.len() as u64;
    let frames_processed = acc.records.iter().filter(|r| !r.was_dropped()).count() as u64;
    let mut energy = EnergyMeter::new(kinds);
    for (dev, &busy) in acc.device_busy.iter().enumerate().take(kinds.len()) {
        energy.record_busy(dev, busy);
    }
    let metrics = RunMetrics {
        frames_total,
        frames_processed,
        frames_dropped: frames_total - frames_processed,
        makespan: acc.makespan.max(1e-12),
        stream_duration: acc.stream_duration,
        device_busy: acc.device_busy,
        device_frames: acc.device_frames,
        latency: acc.latency,
        max_reorder_depth: acc.max_reorder_depth,
        energy,
    };
    StreamReport {
        id: acc.id,
        name: acc.name,
        weight: acc.weight,
        decision: acc.decision,
        records: acc.records,
        metrics,
        rung_log: acc.rung_log,
    }
}

/// Aggregates for one whole fleet run.
pub struct FleetReport {
    pub streams: Vec<StreamReport>,
    /// Fleet time from start to last fate resolution.
    pub makespan: Seconds,
    /// Per-device busy seconds / processed frames (pool slot order).
    pub device_busy: Vec<Seconds>,
    pub device_frames: Vec<u64>,
    pub device_labels: Vec<String>,
}

impl FleetReport {
    pub fn total_frames(&self) -> u64 {
        self.streams.iter().map(|s| s.metrics.frames_total).sum()
    }

    pub fn total_processed(&self) -> u64 {
        self.streams.iter().map(|s| s.metrics.frames_processed).sum()
    }

    /// Aggregate detection throughput over the fleet makespan.
    pub fn aggregate_fps(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.total_processed() as f64 / self.makespan
    }

    pub fn drop_rate(&self) -> f64 {
        let total = self.total_frames();
        if total == 0 {
            return 0.0;
        }
        (total - self.total_processed()) as f64 / total as f64
    }

    /// Utilisation of pool device `dev` over the makespan.
    pub fn utilization(&self, dev: usize) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        (self.device_busy[dev] / self.makespan).min(1.0)
    }

    /// Jain fairness index over admitted streams' weight-normalised
    /// processing throughput σₛ/wₛ (1.0 = perfectly weighted-fair).
    pub fn fairness(&self) -> f64 {
        let xs: Vec<f64> = self
            .streams
            .iter()
            .filter(|s| s.decision.is_admitted())
            .map(|s| s.metrics.processing_fps() / s.weight.max(1e-9))
            .collect();
        jain_index(&xs)
    }

    /// One-line fleet summary.
    pub fn summary(&self) -> String {
        format!(
            "{} streams ({} admitted), {}/{} frames processed ({:.1}% dropped), \
             aggregate σ={:.2} FPS over {:.1}s, Jain fairness {:.3}",
            self.streams.len(),
            self.streams.iter().filter(|s| s.decision.is_admitted()).count(),
            self.total_processed(),
            self.total_frames(),
            self.drop_rate() * 100.0,
            self.aggregate_fps(),
            self.makespan,
            self.fairness(),
        )
    }

    /// Per-stream table.
    pub fn stream_table(&self) -> Table {
        let mut t = Table::new(
            "Per-stream results",
            &[
                "stream", "λ (FPS)", "weight", "decision", "frames", "processed",
                "drop %", "σ (FPS)", "p50 (ms)", "p99 (ms)",
            ],
        );
        for s in self.streams.iter() {
            let fps_in = if s.metrics.stream_duration > 0.0 {
                s.metrics.frames_total as f64 / s.metrics.stream_duration
            } else {
                0.0
            };
            t.row(vec![
                s.name.clone(),
                f(fps_in, 1),
                f(s.weight, 1),
                s.decision.label(),
                format!("{}", s.metrics.frames_total),
                format!("{}", s.metrics.frames_processed),
                f(s.metrics.drop_rate() * 100.0, 1),
                f(s.metrics.processing_fps(), 2),
                f(s.metrics.latency.p50() * 1e3, 0),
                f(s.metrics.latency.p99() * 1e3, 0),
            ]);
        }
        t
    }

    /// Machine-readable run summary (BENCH_*.json trajectories, `--json`
    /// CLI output).
    pub fn to_json(&self) -> Json {
        let makespan = self.makespan;
        let aggregate_fps = self.aggregate_fps();
        let drop_rate = self.drop_rate();
        let fairness = self.fairness();
        let total_frames = self.total_frames();
        let total_processed = self.total_processed();
        let devices: Vec<Json> = self
            .device_labels
            .iter()
            .enumerate()
            .map(|(i, label)| {
                let mut o = BTreeMap::new();
                o.insert("label".to_string(), Json::Str(label.clone()));
                o.insert("frames".to_string(), Json::Num(self.device_frames[i] as f64));
                o.insert("busy_seconds".to_string(), Json::Num(self.device_busy[i]));
                o.insert("utilization".to_string(), Json::Num(self.utilization(i)));
                Json::Obj(o)
            })
            .collect();
        let streams: Vec<Json> = self
            .streams
            .iter()
            .map(|s| {
                let mut o = BTreeMap::new();
                o.insert("id".to_string(), Json::Num(s.id as f64));
                o.insert("name".to_string(), Json::Str(s.name.clone()));
                o.insert("weight".to_string(), Json::Num(s.weight));
                o.insert("decision".to_string(), Json::Str(s.decision.label()));
                o.insert("rung".to_string(), Json::Num(s.decision.rung() as f64));
                o.insert("stride".to_string(), Json::Num(s.decision.stride() as f64));
                o.insert(
                    "frames_total".to_string(),
                    Json::Num(s.metrics.frames_total as f64),
                );
                o.insert(
                    "frames_processed".to_string(),
                    Json::Num(s.metrics.frames_processed as f64),
                );
                o.insert("drop_rate".to_string(), Json::Num(s.metrics.drop_rate()));
                o.insert(
                    "processing_fps".to_string(),
                    Json::Num(s.metrics.processing_fps()),
                );
                o.insert("p50_latency".to_string(), Json::Num(s.metrics.latency.p50()));
                o.insert("p99_latency".to_string(), Json::Num(s.metrics.latency.p99()));
                o.insert(
                    "rung_log".to_string(),
                    Json::Arr(
                        s.rung_log
                            .iter()
                            .map(|&(t, r)| Json::Arr(vec![Json::Num(t), Json::Num(r as f64)]))
                            .collect(),
                    ),
                );
                Json::Obj(o)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("makespan".to_string(), Json::Num(makespan));
        root.insert("aggregate_fps".to_string(), Json::Num(aggregate_fps));
        root.insert("drop_rate".to_string(), Json::Num(drop_rate));
        root.insert("fairness".to_string(), Json::Num(fairness));
        root.insert("frames_total".to_string(), Json::Num(total_frames as f64));
        root.insert(
            "frames_processed".to_string(),
            Json::Num(total_processed as f64),
        );
        root.insert("devices".to_string(), Json::Arr(devices));
        root.insert("streams".to_string(), Json::Arr(streams));
        Json::Obj(root)
    }

    /// Per-device table.
    pub fn device_table(&self) -> Table {
        let mut t = Table::new(
            "Per-device results",
            &["device", "frames", "busy (s)", "utilisation %"],
        );
        for (i, label) in self.device_labels.iter().enumerate() {
            t.row(vec![
                label.clone(),
                format!("{}", self.device_frames[i]),
                f(self.device_busy[i], 1),
                f(self.utilization(i) * 100.0, 1),
            ]);
        }
        t
    }
}

/// Jain's fairness index `(Σx)² / (n·Σx²)`: 1.0 when all `x` are equal,
/// approaching `1/n` as one stream monopolises. Empty or all-zero input
/// reports 1.0 (nothing is being treated unfairly).
pub fn jain_index(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let sum: f64 = xs.iter().sum();
    let sumsq: f64 = xs.iter().map(|x| x * x).sum();
    if sumsq <= 0.0 {
        return 1.0;
    }
    (sum * sum) / (xs.len() as f64 * sumsq)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jain_bounds() {
        assert_eq!(jain_index(&[]), 1.0);
        assert_eq!(jain_index(&[0.0, 0.0]), 1.0);
        assert!((jain_index(&[3.0, 3.0, 3.0]) - 1.0).abs() < 1e-12);
        // One hog out of four: index -> 1/4.
        let skew = jain_index(&[10.0, 0.0, 0.0, 0.0]);
        assert!((skew - 0.25).abs() < 1e-12);
        // Mild imbalance sits in between.
        let mid = jain_index(&[2.0, 1.0]);
        assert!(mid > 0.25 && mid < 1.0);
    }

    fn accum(id: usize, records: Vec<OutputRecord>) -> StreamAccum {
        StreamAccum {
            id,
            name: format!("s{id}"),
            weight: 1.0,
            decision: Decision::Admit { share: 5.0 },
            records,
            latency: Percentiles::new(),
            device_busy: vec![2.0, 0.0],
            device_frames: vec![5, 0],
            makespan: 10.0,
            stream_duration: 10.0,
            max_reorder_depth: 0,
            rung_log: vec![(0.0, 0)],
        }
    }

    fn rec(fid: u64, dropped: bool) -> OutputRecord {
        OutputRecord {
            frame_id: fid,
            capture_ts: fid as f64,
            emit_ts: fid as f64 + 0.1,
            detections: vec![],
            stale_from: if dropped { Some(fid) } else { None },
            processed_by: if dropped { None } else { Some(0) },
        }
    }

    #[test]
    fn finish_stream_counts_fates() {
        let records = vec![rec(0, false), rec(1, true), rec(2, false)];
        let report = finish_stream(accum(0, records), &[DeviceKind::Ncs2, DeviceKind::Ncs2]);
        assert_eq!(report.metrics.frames_total, 3);
        assert_eq!(report.metrics.frames_processed, 2);
        assert_eq!(report.metrics.frames_dropped, 1);
        assert!((report.metrics.processing_fps() - 0.2).abs() < 1e-9);
    }

    #[test]
    fn fleet_report_aggregates() {
        let kinds = [DeviceKind::Ncs2, DeviceKind::Ncs2];
        let a = finish_stream(accum(0, vec![rec(0, false), rec(1, false)]), &kinds);
        let b = finish_stream(accum(1, vec![rec(0, false), rec(1, true)]), &kinds);
        let report = FleetReport {
            streams: vec![a, b],
            makespan: 10.0,
            device_busy: vec![4.0],
            device_frames: vec![3],
            device_labels: vec!["dev0".to_string()],
        };
        assert_eq!(report.total_frames(), 4);
        assert_eq!(report.total_processed(), 3);
        assert!((report.aggregate_fps() - 0.3).abs() < 1e-9);
        assert!((report.drop_rate() - 0.25).abs() < 1e-9);
        assert!((report.utilization(0) - 0.4).abs() < 1e-9);
        let fairness = report.fairness();
        assert!(fairness > 0.5 && fairness <= 1.0, "{fairness}");
        let summary = report.summary();
        assert!(summary.contains("3/4"), "{summary}");
        // Tables render without panicking and with one row per entity.
        assert_eq!(report.stream_table().rows.len(), 2);
        assert_eq!(report.device_table().rows.len(), 1);
    }

    #[test]
    fn report_json_roundtrips_and_carries_key_fields() {
        let kinds = [DeviceKind::Ncs2];
        let a = finish_stream(accum(0, vec![rec(0, false), rec(1, true)]), &kinds);
        let report = FleetReport {
            streams: vec![a],
            makespan: 10.0,
            device_busy: vec![4.0],
            device_frames: vec![3],
            device_labels: vec!["dev0".to_string()],
        };
        let j = report.to_json();
        // Serialise + reparse: the subset writer emits valid JSON.
        let text = j.to_string();
        let back = Json::parse(&text).expect("report JSON must reparse");
        assert_eq!(back.get("frames_total").and_then(Json::as_i64), Some(2));
        assert_eq!(back.get("frames_processed").and_then(Json::as_i64), Some(1));
        let streams = back.get("streams").unwrap().as_arr().unwrap();
        assert_eq!(streams.len(), 1);
        assert_eq!(streams[0].get("name").and_then(Json::as_str), Some("s0"));
        assert_eq!(streams[0].get("decision").and_then(Json::as_str), Some("admit"));
        let rung_log = streams[0].get("rung_log").unwrap().as_arr().unwrap();
        assert_eq!(rung_log.len(), 1);
        let devices = back.get("devices").unwrap().as_arr().unwrap();
        assert!((devices[0].get("utilization").unwrap().as_f64().unwrap() - 0.4).abs() < 1e-9);
    }

    #[test]
    fn stream_report_rung_at_lookup() {
        let kinds = [DeviceKind::Ncs2];
        let mut acc0 = accum(0, vec![rec(0, false)]);
        acc0.rung_log = vec![(0.0, 0), (5.0, 2), (8.0, 1)];
        let report = finish_stream(acc0, &kinds);
        assert_eq!(report.rung_at(0.0), 0);
        assert_eq!(report.rung_at(5.0), 2);
        assert_eq!(report.rung_at(7.9), 2);
        assert_eq!(report.rung_at(9.0), 1);
    }
}
