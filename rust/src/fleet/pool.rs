//! The shared heterogeneous device pool.
//!
//! Where the single-stream engine owns a [`crate::device::Fleet`] whose
//! replicas serve one clip, the pool serves *jobs* — `(stream, frame)`
//! pairs — from however many streams are attached. Dispatch is
//! **work-conserving**: a device is handed a job the moment it is idle
//! and any admitted stream has backlog, so under saturation aggregate
//! throughput approaches Σμᵢ regardless of how load is spread across
//! streams (cross-stream fairness is the dispatcher's job, see
//! [`crate::fleet::registry::FleetRegistry::pick_stream`]).
//!
//! Devices can be attached and detached mid-run: a detached device
//! finishes its in-flight job but is never handed another.

use crate::device::{DeviceInstance, DeviceKind};
use crate::fleet::stream::StreamId;
use crate::types::FrameId;
use crate::util::Rng;

/// One `(stream, frame)` unit of work.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Job {
    pub stream: StreamId,
    pub fid: FrameId,
}

/// A pool member: a device instance plus its in-flight bookkeeping.
#[derive(Debug)]
pub struct PoolDevice {
    pub instance: DeviceInstance,
    /// Detached devices drain their current job and then idle forever.
    pub attached: bool,
    current: Option<Job>,
    pending_service: f64,
    pub busy_seconds: f64,
    pub frames_done: u64,
}

impl PoolDevice {
    fn new(instance: DeviceInstance) -> PoolDevice {
        PoolDevice {
            instance,
            attached: true,
            current: None,
            pending_service: 0.0,
            busy_seconds: 0.0,
            frames_done: 0,
        }
    }

    /// Ready to accept a job.
    pub fn idle(&self) -> bool {
        self.attached && self.current.is_none()
    }

    pub fn current(&self) -> Option<Job> {
        self.current
    }
}

/// The shared pool: devices + dispatch bookkeeping.
#[derive(Debug)]
pub struct DevicePool {
    devices: Vec<PoolDevice>,
}

impl DevicePool {
    pub fn new(instances: Vec<DeviceInstance>) -> DevicePool {
        DevicePool {
            devices: instances.into_iter().map(PoolDevice::new).collect(),
        }
    }

    /// Total devices ever attached (detached ones keep their slot so
    /// device ids stay stable).
    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn devices(&self) -> &[PoolDevice] {
        &self.devices
    }

    /// Attach a new device; returns its stable id.
    pub fn attach(&mut self, instance: DeviceInstance) -> usize {
        self.devices.push(PoolDevice::new(instance));
        self.devices.len() - 1
    }

    /// Detach device `dev`: it completes any in-flight job, then idles.
    pub fn detach(&mut self, dev: usize) {
        self.devices[dev].attached = false;
    }

    /// Aggregate rate Σμᵢ over *attached* devices (admission capacity).
    pub fn attached_rate(&self) -> f64 {
        self.devices
            .iter()
            .filter(|d| d.attached)
            .map(|d| d.instance.rate())
            .sum()
    }

    /// Lowest-indexed idle attached device, if any.
    pub fn next_idle(&self) -> Option<usize> {
        self.devices.iter().position(|d| d.idle())
    }

    /// Start `job` on `dev`; returns the sampled service time in seconds.
    pub fn start(&mut self, dev: usize, job: Job, rng: &mut Rng) -> f64 {
        self.start_scaled(dev, job, 1.0, rng)
    }

    /// Start `job` with its service time divided by `speedup` — the
    /// model-ladder hook: a stream swapped onto a rung that is `speedup`×
    /// faster than the base model costs the device proportionally less
    /// time per frame.
    pub fn start_scaled(&mut self, dev: usize, job: Job, speedup: f64, rng: &mut Rng) -> f64 {
        let d = &mut self.devices[dev];
        assert!(d.idle(), "start on non-idle device {dev}");
        let t = d.instance.sample_service_time(rng) / speedup.max(1e-9);
        d.current = Some(job);
        d.pending_service = t;
        t
    }

    /// Complete `dev`'s in-flight job; returns `(job, service_seconds)`.
    pub fn complete(&mut self, dev: usize) -> (Job, f64) {
        let d = &mut self.devices[dev];
        let job = d.current.take().expect("complete on idle device");
        d.busy_seconds += d.pending_service;
        d.frames_done += 1;
        (job, d.pending_service)
    }

    /// Device kinds in slot order (energy accounting).
    pub fn kinds(&self) -> Vec<DeviceKind> {
        self.devices.iter().map(|d| d.instance.kind).collect()
    }

    /// Human labels in slot order.
    pub fn labels(&self) -> Vec<String> {
        self.devices
            .iter()
            .enumerate()
            .map(|(i, d)| {
                format!(
                    "{}#{i} ({:.1} FPS{})",
                    d.instance.kind.label(),
                    d.instance.rate(),
                    if d.attached { "" } else { ", detached" }
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DetectorModelId;

    fn pool(rates: &[f64]) -> DevicePool {
        DevicePool::new(
            rates
                .iter()
                .enumerate()
                .map(|(i, &r)| {
                    DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, i, r)
                })
                .collect(),
        )
    }

    #[test]
    fn start_complete_accounting() {
        let mut p = pool(&[2.5, 13.5]);
        let mut rng = Rng::new(1);
        assert_eq!(p.next_idle(), Some(0));
        let t = p.start(0, Job { stream: 3, fid: 7 }, &mut rng);
        assert!(t > 0.0);
        assert_eq!(p.next_idle(), Some(1));
        assert_eq!(p.devices()[0].current(), Some(Job { stream: 3, fid: 7 }));
        let (job, service) = p.complete(0);
        assert_eq!(job, Job { stream: 3, fid: 7 });
        assert!((service - t).abs() < 1e-12);
        assert_eq!(p.devices()[0].frames_done, 1);
        assert!((p.devices()[0].busy_seconds - t).abs() < 1e-12);
        assert_eq!(p.next_idle(), Some(0));
    }

    #[test]
    fn detached_devices_are_skipped() {
        let mut p = pool(&[2.5, 2.5]);
        p.detach(0);
        assert_eq!(p.next_idle(), Some(1));
        assert!((p.attached_rate() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn detach_mid_service_drains_then_idles() {
        let mut p = pool(&[2.5]);
        let mut rng = Rng::new(2);
        p.start(0, Job { stream: 0, fid: 0 }, &mut rng);
        p.detach(0);
        // Still completes its job...
        let (job, _) = p.complete(0);
        assert_eq!(job.fid, 0);
        // ...but never becomes idle again.
        assert_eq!(p.next_idle(), None);
    }

    #[test]
    fn attach_returns_stable_ids() {
        let mut p = pool(&[2.5]);
        let id = p.attach(DeviceInstance::with_rate(
            DeviceKind::FastCpu,
            DetectorModelId::Yolov3,
            1,
            13.5,
        ));
        assert_eq!(id, 1);
        assert_eq!(p.len(), 2);
        assert!((p.attached_rate() - 16.0).abs() < 1e-12);
        assert_eq!(p.labels().len(), 2);
    }

    #[test]
    fn scaled_start_divides_service_time() {
        // Jitter-free instance so the ratio check is exact.
        let mut inst =
            DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, 0, 2.5);
        inst.jitter_cv = 0.0;
        let mut p = DevicePool::new(vec![inst]);
        let mut rng = Rng::new(4);
        let t = p.start_scaled(0, Job { stream: 0, fid: 0 }, 2.5, &mut rng);
        assert!((t - 0.4 / 2.5).abs() < 1e-12, "t {t}");
        let (_, service) = p.complete(0);
        assert!((service - t).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "non-idle")]
    fn double_start_panics() {
        let mut p = pool(&[2.5]);
        let mut rng = Rng::new(3);
        p.start(0, Job { stream: 0, fid: 0 }, &mut rng);
        p.start(0, Job { stream: 0, fid: 1 }, &mut rng);
    }
}
