//! Greedy class-aware non-maximum suppression.
//!
//! The paper's post-processing step (§II-B): detectors emit one candidate
//! per grid cell; NMS keeps the highest-scoring box among mutual overlaps.

use crate::types::Detection;

/// Greedy NMS: sort by score descending, suppress same-class boxes with
/// IoU above `iou_thresh`. Returns kept detections in score order.
pub fn nms(mut dets: Vec<Detection>, iou_thresh: f32) -> Vec<Detection> {
    dets.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap_or(std::cmp::Ordering::Equal));
    let mut kept: Vec<Detection> = Vec::with_capacity(dets.len().min(16));
    'outer: for d in dets {
        for k in &kept {
            if k.class_id == d.class_id && k.bbox.iou(&d.bbox) > iou_thresh {
                continue 'outer;
            }
        }
        kept.push(d);
    }
    kept
}

/// Threshold + NMS convenience used by detector backends.
pub fn postprocess(dets: Vec<Detection>, score_thresh: f32, iou_thresh: f32) -> Vec<Detection> {
    let filtered: Vec<Detection> = dets.into_iter().filter(|d| d.score >= score_thresh).collect();
    nms(filtered, iou_thresh)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::BBox;

    fn det(cx: f32, cy: f32, s: f32, class_id: usize, score: f32) -> Detection {
        Detection {
            bbox: BBox::new(cx, cy, s, s),
            class_id,
            score,
        }
    }

    #[test]
    fn suppresses_overlapping_same_class() {
        let dets = vec![
            det(0.5, 0.5, 0.2, 0, 0.9),
            det(0.51, 0.5, 0.2, 0, 0.8), // overlaps first
            det(0.9, 0.9, 0.1, 0, 0.7),  // far away
        ];
        let kept = nms(dets, 0.45);
        assert_eq!(kept.len(), 2);
        assert_eq!(kept[0].score, 0.9);
        assert_eq!(kept[1].score, 0.7);
    }

    #[test]
    fn keeps_overlapping_different_classes() {
        let dets = vec![det(0.5, 0.5, 0.2, 0, 0.9), det(0.5, 0.5, 0.2, 1, 0.8)];
        assert_eq!(nms(dets, 0.45).len(), 2);
    }

    #[test]
    fn empty_input() {
        assert!(nms(vec![], 0.5).is_empty());
    }

    #[test]
    fn keeps_highest_score_of_cluster() {
        let dets = vec![
            det(0.5, 0.5, 0.2, 2, 0.6),
            det(0.5, 0.5, 0.2, 2, 0.95),
            det(0.5, 0.5, 0.2, 2, 0.7),
        ];
        let kept = nms(dets, 0.45);
        assert_eq!(kept.len(), 1);
        assert_eq!(kept[0].score, 0.95);
    }

    #[test]
    fn postprocess_thresholds_first() {
        let dets = vec![det(0.5, 0.5, 0.2, 0, 0.3), det(0.2, 0.2, 0.1, 0, 0.8)];
        let kept = postprocess(dets, 0.5, 0.45);
        assert_eq!(kept.len(), 1);
        assert_eq!(kept[0].score, 0.8);
    }

    #[test]
    fn nms_is_idempotent() {
        let dets = vec![
            det(0.5, 0.5, 0.2, 0, 0.9),
            det(0.52, 0.5, 0.2, 0, 0.8),
            det(0.1, 0.1, 0.05, 1, 0.6),
        ];
        let once = nms(dets, 0.45);
        let twice = nms(once.clone(), 0.45);
        assert_eq!(once, twice);
    }
}
