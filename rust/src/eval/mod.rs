//! Detection evaluation: greedy NMS and VOC-style mAP.
//!
//! The paper reports mAP over *all frames of the input video* — dropped
//! frames are evaluated with their reused (stale) detections, which is
//! exactly what couples frame dropping to accuracy (§II). The evaluator
//! here consumes the synchronizer's [`OutputRecord`] stream plus the
//! clip's ground truth and computes that number.

pub mod nms;
pub mod map;

pub use map::{evaluate_map, MapResult};
pub use nms::nms;
