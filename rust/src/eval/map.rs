//! VOC-style mean average precision over a whole clip.
//!
//! Matching is the standard protocol: per class, detections across all
//! frames are ranked by confidence; each is greedily matched to the
//! highest-IoU unmatched ground-truth box *in its frame* (TP if
//! IoU ≥ `iou_thresh`, else FP); AP is the area under the
//! precision-envelope/recall curve (VOC 2010+ all-point interpolation);
//! mAP averages over classes that have ground truth.

use crate::types::{Detection, GtBox};

/// Per-class and aggregate AP results.
#[derive(Debug, Clone)]
pub struct MapResult {
    /// AP per class id (None when the class has no ground truth).
    pub per_class: Vec<Option<f64>>,
    pub map: f64,
    pub total_gt: usize,
    pub total_dets: usize,
}

/// Evaluate mAP for `detections[frame]` against `ground_truth[frame]`.
///
/// The two slices must have the same length (one entry per video frame —
/// dropped frames included, carrying their reused detections).
pub fn evaluate_map(
    detections: &[Vec<Detection>],
    ground_truth: &[&[GtBox]],
    num_classes: usize,
    iou_thresh: f32,
) -> MapResult {
    assert_eq!(
        detections.len(),
        ground_truth.len(),
        "detections and ground truth must cover the same frames"
    );

    let total_dets = detections.iter().map(|d| d.len()).sum();
    let total_gt = ground_truth.iter().map(|g| g.len()).sum();

    let mut per_class: Vec<Option<f64>> = Vec::with_capacity(num_classes);
    for class_id in 0..num_classes {
        per_class.push(class_ap(detections, ground_truth, class_id, iou_thresh));
    }

    let present: Vec<f64> = per_class.iter().filter_map(|x| *x).collect();
    let map = if present.is_empty() {
        0.0
    } else {
        present.iter().sum::<f64>() / present.len() as f64
    };

    MapResult {
        per_class,
        map,
        total_gt,
        total_dets,
    }
}

fn class_ap(
    detections: &[Vec<Detection>],
    ground_truth: &[&[GtBox]],
    class_id: usize,
    iou_thresh: f32,
) -> Option<f64> {
    // Collect class GT count and per-frame GT indices.
    let npos: usize = ground_truth
        .iter()
        .map(|g| g.iter().filter(|gt| gt.class_id == class_id).count())
        .sum();
    if npos == 0 {
        return None;
    }

    // (score, frame, det) for this class, ranked by confidence.
    let mut ranked: Vec<(f32, usize, &Detection)> = Vec::new();
    for (f, dets) in detections.iter().enumerate() {
        for d in dets.iter().filter(|d| d.class_id == class_id) {
            ranked.push((d.score, f, d));
        }
    }
    ranked.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal));

    // Greedy matching; GT may be claimed once.
    let mut claimed: Vec<Vec<bool>> = ground_truth
        .iter()
        .map(|g| vec![false; g.len()])
        .collect();
    let mut tps: Vec<bool> = Vec::with_capacity(ranked.len());
    for (_, f, d) in &ranked {
        let gts = ground_truth[*f];
        let mut best = -1.0f32;
        let mut best_i = usize::MAX;
        for (i, gt) in gts.iter().enumerate() {
            if gt.class_id != class_id || claimed[*f][i] {
                continue;
            }
            let iou = d.bbox.iou(&gt.bbox);
            if iou > best {
                best = iou;
                best_i = i;
            }
        }
        if best >= iou_thresh && best_i != usize::MAX {
            claimed[*f][best_i] = true;
            tps.push(true);
        } else {
            tps.push(false);
        }
    }

    // Precision/recall curve + all-point interpolated AP.
    let mut tp_cum = 0usize;
    let mut fp_cum = 0usize;
    let mut recalls: Vec<f64> = Vec::with_capacity(tps.len());
    let mut precisions: Vec<f64> = Vec::with_capacity(tps.len());
    for &is_tp in &tps {
        if is_tp {
            tp_cum += 1;
        } else {
            fp_cum += 1;
        }
        recalls.push(tp_cum as f64 / npos as f64);
        precisions.push(tp_cum as f64 / (tp_cum + fp_cum) as f64);
    }

    // Precision envelope (monotone non-increasing from the right).
    for i in (0..precisions.len().saturating_sub(1)).rev() {
        if precisions[i] < precisions[i + 1] {
            precisions[i] = precisions[i + 1];
        }
    }

    // Integrate over recall steps.
    let mut ap = 0.0;
    let mut prev_r = 0.0;
    for i in 0..recalls.len() {
        let dr = recalls[i] - prev_r;
        if dr > 0.0 {
            ap += dr * precisions[i];
            prev_r = recalls[i];
        }
    }
    Some(ap)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{BBox, Detection, GtBox};

    fn gt(cx: f32, cy: f32, s: f32, class_id: usize) -> GtBox {
        GtBox {
            bbox: BBox::new(cx, cy, s, s),
            class_id,
            track_id: 0,
        }
    }

    fn det(cx: f32, cy: f32, s: f32, class_id: usize, score: f32) -> Detection {
        Detection {
            bbox: BBox::new(cx, cy, s, s),
            class_id,
            score,
        }
    }

    #[test]
    fn perfect_detections_give_map_one() {
        let gts = vec![vec![gt(0.5, 0.5, 0.2, 0), gt(0.2, 0.2, 0.1, 1)]];
        let dets = vec![vec![det(0.5, 0.5, 0.2, 0, 0.9), det(0.2, 0.2, 0.1, 1, 0.8)]];
        let gt_refs: Vec<&[GtBox]> = gts.iter().map(|g| g.as_slice()).collect();
        let r = evaluate_map(&dets, &gt_refs, 3, 0.5);
        assert!((r.map - 1.0).abs() < 1e-9, "map = {}", r.map);
        assert_eq!(r.per_class[2], None); // class 2 has no GT
    }

    #[test]
    fn no_detections_give_zero() {
        let gts = vec![vec![gt(0.5, 0.5, 0.2, 0)]];
        let dets = vec![vec![]];
        let gt_refs: Vec<&[GtBox]> = gts.iter().map(|g| g.as_slice()).collect();
        let r = evaluate_map(&dets, &gt_refs, 3, 0.5);
        assert_eq!(r.map, 0.0);
    }

    #[test]
    fn misaligned_box_is_fp() {
        let gts = vec![vec![gt(0.5, 0.5, 0.2, 0)]];
        // Far-off detection: IoU < 0.5.
        let dets = vec![vec![det(0.8, 0.8, 0.2, 0, 0.9)]];
        let gt_refs: Vec<&[GtBox]> = gts.iter().map(|g| g.as_slice()).collect();
        let r = evaluate_map(&dets, &gt_refs, 3, 0.5);
        assert_eq!(r.map, 0.0);
    }

    #[test]
    fn duplicate_detections_counted_once() {
        let gts = vec![vec![gt(0.5, 0.5, 0.2, 0)]];
        let dets = vec![vec![
            det(0.5, 0.5, 0.2, 0, 0.9),
            det(0.5, 0.5, 0.2, 0, 0.8), // duplicate -> FP
        ]];
        let gt_refs: Vec<&[GtBox]> = gts.iter().map(|g| g.as_slice()).collect();
        let r = evaluate_map(&dets, &gt_refs, 3, 0.5);
        // recall hits 1.0 at precision 1.0 first, so AP stays 1.0 for the class.
        assert!((r.map - 1.0).abs() < 1e-9);
    }

    #[test]
    fn low_scored_fp_ranked_after_tp_keeps_ap() {
        // FP with lower score than all TPs: AP unaffected (classic VOC property).
        let gts = vec![vec![gt(0.3, 0.3, 0.2, 0), gt(0.7, 0.7, 0.2, 0)]];
        let dets = vec![vec![
            det(0.3, 0.3, 0.2, 0, 0.9),
            det(0.7, 0.7, 0.2, 0, 0.85),
            det(0.1, 0.9, 0.1, 0, 0.1),
        ]];
        let gt_refs: Vec<&[GtBox]> = gts.iter().map(|g| g.as_slice()).collect();
        let r = evaluate_map(&dets, &gt_refs, 3, 0.5);
        assert!((r.map - 1.0).abs() < 1e-9);
    }

    #[test]
    fn high_scored_fp_reduces_ap() {
        let gts = vec![vec![gt(0.3, 0.3, 0.2, 0)]];
        let dets = vec![vec![
            det(0.9, 0.9, 0.1, 0, 0.95), // confident FP ranked first
            det(0.3, 0.3, 0.2, 0, 0.5),
        ]];
        let gt_refs: Vec<&[GtBox]> = gts.iter().map(|g| g.as_slice()).collect();
        let r = evaluate_map(&dets, &gt_refs, 3, 0.5);
        assert!(r.map < 1.0 && r.map > 0.0);
        assert!((r.map - 0.5).abs() < 1e-9); // precision 1/2 at recall 1
    }

    #[test]
    fn cross_frame_ranking() {
        // Two frames, one GT each; detector confident+right on frame 0,
        // confident+wrong on frame 1.
        let gts = vec![vec![gt(0.4, 0.4, 0.2, 0)], vec![gt(0.6, 0.6, 0.2, 0)]];
        let dets = vec![
            vec![det(0.4, 0.4, 0.2, 0, 0.9)],
            vec![det(0.1, 0.1, 0.1, 0, 0.95)],
        ];
        let gt_refs: Vec<&[GtBox]> = gts.iter().map(|g| g.as_slice()).collect();
        let r = evaluate_map(&dets, &gt_refs, 3, 0.5);
        // Ranked: FP(0.95), TP(0.9). Precisions: 0, 1/2. Recall reaches 0.5.
        assert!((r.map - 0.25).abs() < 1e-9, "map = {}", r.map);
    }

    #[test]
    #[should_panic(expected = "same frames")]
    fn frame_count_mismatch_panics() {
        let gts: Vec<Vec<GtBox>> = vec![vec![]];
        let gt_refs: Vec<&[GtBox]> = gts.iter().map(|g| g.as_slice()).collect();
        evaluate_map(&[vec![], vec![]], &gt_refs, 3, 0.5);
    }

    #[test]
    fn stale_detections_degrade_map() {
        // The paper's core mechanism: boxes from frame t reused at t+k
        // lose IoU as the object moves. 10 frames, object moving right.
        let mut gts: Vec<Vec<GtBox>> = Vec::new();
        let mut fresh: Vec<Vec<Detection>> = Vec::new();
        let mut stale: Vec<Vec<Detection>> = Vec::new();
        for f in 0..10 {
            let cx = 0.2 + 0.06 * f as f32;
            gts.push(vec![gt(cx, 0.5, 0.15, 0)]);
            fresh.push(vec![det(cx, 0.5, 0.15, 0, 0.9)]);
            // stale: reuse frame 0's detection for frames 0..4, frame 5's for 5..9
            let src = if f < 5 { 0.2 } else { 0.2 + 0.06 * 5.0 };
            stale.push(vec![det(src, 0.5, 0.15, 0, 0.9)]);
        }
        let gt_refs: Vec<&[GtBox]> = gts.iter().map(|g| g.as_slice()).collect();
        let fresh_map = evaluate_map(&fresh, &gt_refs, 3, 0.5).map;
        let stale_map = evaluate_map(&stale, &gt_refs, 3, 0.5).map;
        assert!((fresh_map - 1.0).abs() < 1e-9);
        assert!(stale_map < fresh_map, "stale {stale_map} < fresh {fresh_map}");
    }
}
