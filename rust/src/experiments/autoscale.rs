//! Autoscale sweeps: closed-loop adaptation vs static baselines, in
//! virtual time.
//!
//! Three scenarios exercise the controller (see EXPERIMENTS.md
//! §Autoscale for the measured numbers):
//!
//! * [`step_load`] — the acceptance sweep: a 2× offered-load step on a
//!   fixed 4-device pool, comparing static-n + stride-only degradation,
//!   static-n + model-ladder admission, and ladder + device autoscale
//!   on **delivered mAP** during the overload window, worst p99, and
//!   how fast full-quality models are restored after the load subsides.
//! * [`diurnal`] — a day-shaped ramp (night → morning → peak → night):
//!   the device controller must track offered load in both directions.
//! * [`device_failure`] — three of nine devices die mid-run: the
//!   controller re-attaches replacements and delivered quality recovers.
//!
//! Delivered mAP is an analytic composition, not a detector run: each
//! output record contributes its rung's intrinsic quality
//! ([`ModelLadder::quality`], the calibrated-profile proxy), scaled by
//! [`staleness_factor`] for stale-box reuse — the same staleness model
//! calibrated against the paper's §II-B mAP-under-dropping anchor.

use crate::autoscale::ladder::{staleness_factor, ModelLadder};
use crate::autoscale::policy::AutoscaleConfig;
use crate::autoscale::runner::{run_autoscale_sim, AutoscaleOutcome};
use crate::experiments::fleet::pool_of;
use crate::control::{ControlAction, ControlEvent};
use crate::fleet::admission::AdmissionPolicy;
use crate::fleet::metrics::StreamReport;
use crate::fleet::sim::{run_fleet, Scenario};
use crate::fleet::stream::StreamSpec;
use crate::util::json::Json;
use crate::util::stats::Percentiles;
use crate::util::table::{f, Table};
use std::collections::BTreeMap;

/// Overload step-on / step-off times for [`step_load`].
pub const STEP_T_ON: f64 = 40.0;
pub const STEP_T_OFF: f64 = 100.0;

/// Mean delivered quality of the records captured inside `window`:
/// processed frames contribute their rung's intrinsic quality, stale
/// fills contribute the *source* frame's rung quality decayed by the
/// reuse age, self-stale records (nothing to reuse) contribute zero.
pub fn delivered_map(streams: &[StreamReport], ladder: &ModelLadder, window: (f64, f64)) -> f64 {
    let (lo, hi) = window;
    let mut total = 0.0;
    let mut n = 0usize;
    for s in streams {
        for rec in &s.records {
            if rec.capture_ts < lo || rec.capture_ts >= hi {
                continue;
            }
            n += 1;
            match rec.stale_from {
                None => total += ladder.quality(s.rung_at(rec.capture_ts)),
                Some(src) if src == rec.frame_id => {} // nothing reused
                Some(src) => {
                    let src_rec = &s.records[src as usize];
                    let age = (rec.capture_ts - src_rec.capture_ts).max(0.0);
                    total +=
                        ladder.quality(s.rung_at(src_rec.capture_ts)) * staleness_factor(age);
                }
            }
        }
    }
    if n == 0 {
        0.0
    } else {
        total / n as f64
    }
}

/// p99 output latency over the records captured inside `window`.
pub fn windowed_p99(streams: &[StreamReport], window: (f64, f64)) -> f64 {
    let (lo, hi) = window;
    let mut p = Percentiles::new();
    for s in streams {
        for rec in &s.records {
            if rec.capture_ts >= lo && rec.capture_ts < hi {
                p.push((rec.emit_ts - rec.capture_ts).max(0.0));
            }
        }
    }
    p.p99()
}

/// Seconds after `t_off` until every stream still alive past `t_off` is
/// back on rung 0 for good; infinite if any never recovers.
pub fn rung_recovery_seconds(streams: &[StreamReport], t_off: f64) -> f64 {
    let mut worst = 0.0f64;
    for s in streams {
        if s.records.last().map_or(true, |r| r.capture_ts < t_off) {
            continue; // stream ended before the load subsided
        }
        let mut settled: Option<f64> = None;
        for &(t, r) in &s.rung_log {
            settled = if r == 0 { Some(t) } else { None };
        }
        match settled {
            Some(t) => worst = worst.max((t - t_off).max(0.0)),
            None => return f64::INFINITY,
        }
    }
    worst
}

/// One policy's step-load outcome.
#[derive(Debug, Clone)]
pub struct StepLoadOutcome {
    pub policy: &'static str,
    /// Delivered mAP over the overload window `[t_on, t_off)`.
    pub overload_map: f64,
    /// p99 output latency over the overload window (all streams).
    pub overload_p99: f64,
    /// Seconds after `t_off` until full-quality models are restored.
    pub recovery_seconds: f64,
    pub peak_devices: usize,
    pub final_devices: usize,
    pub control_actions: usize,
}

fn eth_ladder() -> ModelLadder {
    ModelLadder::from_profiles("eth_sunnyday")
}

/// The step-load scenario: 3 steady 2.5-FPS cams on a 4 × 2.5-FPS pool
/// (comfortable), 5 more cams burst in at `STEP_T_ON` (Σλ = 20 vs
/// capacity 9.5 — ≈ 2× overload) and leave at `STEP_T_OFF`.
fn step_scenario(policy: AdmissionPolicy, seed: u64) -> Scenario {
    let base: Vec<StreamSpec> = (0..3)
        .map(|i| StreamSpec::new(&format!("cam{i}"), 2.5, 400).with_window(4))
        .collect();
    let mut events = Vec::new();
    for i in 0..5 {
        events.push(ControlEvent {
            at: STEP_T_ON,
            action: ControlAction::AttachStream(
                StreamSpec::new(&format!("burst{i}"), 2.5, 150).with_window(4),
            ),
        });
    }
    for i in 0..5 {
        events.push(ControlEvent {
            at: STEP_T_OFF,
            action: ControlAction::DetachStream(3 + i),
        });
    }
    Scenario::new(pool_of(4, 2.5), base)
        .with_admission(policy)
        .with_events(events)
        .with_seed(seed)
}

fn step_outcome(
    policy: &'static str,
    out: &AutoscaleOutcome,
    ladder: &ModelLadder,
) -> StepLoadOutcome {
    let window = (STEP_T_ON, STEP_T_OFF);
    StepLoadOutcome {
        policy,
        overload_map: delivered_map(&out.report.streams, ladder, window),
        overload_p99: windowed_p99(&out.report.streams, window),
        recovery_seconds: rung_recovery_seconds(&out.report.streams, STEP_T_OFF),
        peak_devices: out
            .device_timeline
            .iter()
            .map(|&(_, n)| n)
            .max()
            .unwrap_or(0),
        final_devices: out.final_devices(),
        control_actions: out.controller_device_actions() + out.rung_actions,
    }
}

/// Static (uncontrolled) run wrapped into the same outcome shape.
fn static_outcome(
    policy_name: &'static str,
    scenario: &Scenario,
    ladder: &ModelLadder,
) -> StepLoadOutcome {
    let report = run_fleet(scenario);
    let window = (STEP_T_ON, STEP_T_OFF);
    StepLoadOutcome {
        policy: policy_name,
        overload_map: delivered_map(&report.streams, ladder, window),
        overload_p99: windowed_p99(&report.streams, window),
        recovery_seconds: rung_recovery_seconds(&report.streams, STEP_T_OFF),
        peak_devices: scenario.devices.len(),
        final_devices: scenario.devices.len(),
        control_actions: 0,
    }
}

/// The acceptance sweep: stride-only vs ladder admission vs
/// ladder + autoscale under a 2× load step.
pub fn step_load(seed: u64) -> (Table, Vec<StepLoadOutcome>) {
    let ladder = eth_ladder();
    let cfg = AutoscaleConfig {
        max_devices: 12,
        ..AutoscaleConfig::default()
    }
    .with_ladder(ladder.clone());

    let stride = static_outcome(
        "static-n + stride",
        &step_scenario(AdmissionPolicy::default(), seed),
        &ladder,
    );
    let ladder_only = static_outcome(
        "static-n + ladder",
        &step_scenario(cfg.admission(), seed),
        &ladder,
    );
    let scenario = step_scenario(cfg.admission(), seed);
    let auto = run_autoscale_sim(&scenario, &cfg);
    let auto = step_outcome("ladder + autoscale", &auto, &ladder);

    let outcomes = vec![stride, ladder_only, auto];
    let mut t = Table::new(
        "Step load (2× at t=40..100): delivered mAP / p99 under three degradation policies",
        &[
            "policy", "mAP @overload", "p99 (s)", "recovery (s)", "peak devices",
            "final devices", "actions",
        ],
    );
    for o in &outcomes {
        t.row(vec![
            o.policy.to_string(),
            f(o.overload_map * 100.0, 1),
            f(o.overload_p99, 2),
            if o.recovery_seconds.is_finite() {
                f(o.recovery_seconds, 1)
            } else {
                "never".to_string()
            },
            format!("{}", o.peak_devices),
            format!("{}", o.final_devices),
            format!("{}", o.control_actions),
        ]);
    }
    (t, outcomes)
}

/// One diurnal phase's end-state.
#[derive(Debug, Clone)]
pub struct DiurnalPoint {
    pub phase: &'static str,
    pub until: f64,
    /// Offered load Σλ during the phase (FPS).
    pub offered: f64,
    /// Attached devices at phase end.
    pub devices: usize,
    /// p99 output latency over the phase.
    pub p99: f64,
}

/// Day-shaped ramp: 2 cams overnight, +2 in the morning, +4 at the
/// peak, everyone but the base gone at night. The device controller
/// must track the load both up and down.
pub fn diurnal(seed: u64) -> (Table, Vec<DiurnalPoint>, AutoscaleOutcome) {
    let ladder = eth_ladder();
    let cfg = AutoscaleConfig {
        max_devices: 12,
        ..AutoscaleConfig::default()
    }
    .with_ladder(ladder.clone());

    let base: Vec<StreamSpec> = (0..2)
        .map(|i| StreamSpec::new(&format!("cam{i}"), 2.5, 480).with_window(4))
        .collect();
    let mut events = Vec::new();
    for i in 0..2 {
        events.push(ControlEvent {
            at: 40.0,
            action: ControlAction::AttachStream(
                StreamSpec::new(&format!("morning{i}"), 2.5, 260).with_window(4),
            ),
        });
    }
    for i in 0..4 {
        events.push(ControlEvent {
            at: 80.0,
            action: ControlAction::AttachStream(
                StreamSpec::new(&format!("peak{i}"), 2.5, 140).with_window(4),
            ),
        });
    }
    for id in 2..8 {
        events.push(ControlEvent {
            at: 130.0,
            action: ControlAction::DetachStream(id),
        });
    }
    let scenario = Scenario::new(pool_of(3, 2.5), base)
        .with_admission(cfg.admission())
        .with_events(events)
        .with_seed(seed);
    let out = run_autoscale_sim(&scenario, &cfg);

    let phases: [(&'static str, f64, f64, f64); 4] = [
        ("night", 40.0, 0.0, 5.0),
        ("morning", 80.0, 40.0, 10.0),
        ("peak", 130.0, 80.0, 20.0),
        ("night again", 192.0, 130.0, 5.0),
    ];
    let mut points = Vec::new();
    let mut t = Table::new(
        "Diurnal ramp: device count tracks offered load (ladder + autoscale)",
        &["phase", "until (s)", "offered λ", "devices", "p99 (s)"],
    );
    for (phase, until, from, offered) in phases {
        let p = DiurnalPoint {
            phase,
            until,
            offered,
            devices: out.devices_at(until - 1e-6),
            p99: windowed_p99(&out.report.streams, (from, until)),
        };
        t.row(vec![
            p.phase.to_string(),
            f(p.until, 0),
            f(p.offered, 1),
            format!("{}", p.devices),
            f(p.p99, 2),
        ]);
        points.push(p);
    }
    (t, points, out)
}

/// Device-failure outcome (controller vs frozen pool).
#[derive(Debug, Clone)]
pub struct FailureOutcome {
    pub policy: &'static str,
    /// Delivered mAP over the 30 s after the failure.
    pub post_failure_map: f64,
    pub post_failure_p99: f64,
    /// Devices attached at the end of the run.
    pub final_devices: usize,
    /// Seconds until pool capacity is back above the band floor
    /// (infinite when no controller reacts).
    pub recovery_seconds: f64,
}

/// 8 × 2.5-FPS streams on a converged 9-device pool; 3 devices fail at
/// t=30. With the controller, replacements restore capacity within a
/// few cooldowns; without it, quality stays degraded.
pub fn device_failure(seed: u64) -> (Table, Vec<FailureOutcome>) {
    let ladder = eth_ladder();
    let cfg = AutoscaleConfig {
        max_devices: 12,
        ..AutoscaleConfig::default()
    }
    .with_ladder(ladder.clone());

    let streams: Vec<StreamSpec> = (0..8)
        .map(|i| StreamSpec::new(&format!("cam{i}"), 2.5, 500).with_window(4))
        .collect();
    let events: Vec<ControlEvent> = (0..3)
        .map(|dev| ControlEvent {
            at: 30.0,
            action: ControlAction::DetachDevice(dev),
        })
        .collect();
    let scenario = Scenario::new(pool_of(9, 2.5), streams)
        .with_admission(cfg.admission())
        .with_events(events)
        .with_seed(seed);

    let window = (30.0, 60.0);
    // Band floor for 8 × 2.5-FPS slow streams: Σλ / util.
    let cap_floor = 20.0 / cfg.target_utilization;

    let frozen_report = run_fleet(&scenario);
    let frozen = FailureOutcome {
        policy: "no controller",
        post_failure_map: delivered_map(&frozen_report.streams, &ladder, window),
        post_failure_p99: windowed_p99(&frozen_report.streams, window),
        final_devices: 6,
        recovery_seconds: f64::INFINITY,
    };

    let out = run_autoscale_sim(&scenario, &cfg);
    // First time after the failure when attached capacity clears the
    // floor again (device_timeline carries counts; all devices are
    // 2.5-FPS templates here).
    let recovery = out
        .device_timeline
        .iter()
        .find(|&&(t, n)| t >= 30.0 && n as f64 * 2.5 >= cap_floor)
        .map(|&(t, _)| t - 30.0)
        .unwrap_or(f64::INFINITY);
    let controlled = FailureOutcome {
        policy: "autoscale",
        post_failure_map: delivered_map(&out.report.streams, &ladder, window),
        post_failure_p99: windowed_p99(&out.report.streams, window),
        final_devices: out.final_devices(),
        recovery_seconds: recovery,
    };

    let outcomes = vec![frozen, controlled];
    let mut t = Table::new(
        "Device failure (3 of 9 die at t=30): recovery with and without the controller",
        &["policy", "mAP @[30,60)", "p99 (s)", "final devices", "capacity recovery (s)"],
    );
    for o in &outcomes {
        t.row(vec![
            o.policy.to_string(),
            f(o.post_failure_map * 100.0, 1),
            f(o.post_failure_p99, 2),
            format!("{}", o.final_devices),
            if o.recovery_seconds.is_finite() {
                f(o.recovery_seconds, 1)
            } else {
                "never".to_string()
            },
        ]);
    }
    (t, outcomes)
}

/// Machine-readable sweep results (the `--json` surface of
/// `eva autoscale`): only the requested scenario is run and emitted
/// (`"all"` runs all three). `None` for an unknown scenario name.
pub fn autoscale_json(seed: u64, scenario: &str) -> Option<Json> {
    if !matches!(scenario, "step" | "diurnal" | "failure" | "all") {
        return None;
    }
    let mut root = BTreeMap::new();
    root.insert("seed".into(), Json::Num(seed as f64));
    if matches!(scenario, "step" | "all") {
        let (_, step) = step_load(seed);
        root.insert("step_load".into(), Json::Arr(step_json(&step)));
    }
    if matches!(scenario, "diurnal" | "all") {
        let (_, points, _) = diurnal(seed);
        root.insert("diurnal".into(), Json::Arr(diurnal_json(&points)));
    }
    if matches!(scenario, "failure" | "all") {
        let (_, failure) = device_failure(seed);
        root.insert("device_failure".into(), Json::Arr(failure_json(&failure)));
    }
    Some(Json::Obj(root))
}

fn finite_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

fn step_json(step: &[StepLoadOutcome]) -> Vec<Json> {
    step.iter()
        .map(|o| {
            let mut m = BTreeMap::new();
            m.insert("policy".into(), Json::Str(o.policy.to_string()));
            m.insert("overload_map".into(), Json::Num(o.overload_map));
            m.insert("overload_p99".into(), Json::Num(o.overload_p99));
            m.insert("recovery_seconds".into(), finite_or_null(o.recovery_seconds));
            m.insert("peak_devices".into(), Json::Num(o.peak_devices as f64));
            m.insert("final_devices".into(), Json::Num(o.final_devices as f64));
            m.insert("control_actions".into(), Json::Num(o.control_actions as f64));
            Json::Obj(m)
        })
        .collect()
}

fn diurnal_json(points: &[DiurnalPoint]) -> Vec<Json> {
    points
        .iter()
        .map(|p| {
            let mut m = BTreeMap::new();
            m.insert("phase".into(), Json::Str(p.phase.to_string()));
            m.insert("until".into(), Json::Num(p.until));
            m.insert("offered".into(), Json::Num(p.offered));
            m.insert("devices".into(), Json::Num(p.devices as f64));
            m.insert("p99".into(), Json::Num(p.p99));
            Json::Obj(m)
        })
        .collect()
}

fn failure_json(failure: &[FailureOutcome]) -> Vec<Json> {
    failure
        .iter()
        .map(|o| {
            let mut m = BTreeMap::new();
            m.insert("policy".into(), Json::Str(o.policy.to_string()));
            m.insert("post_failure_map".into(), Json::Num(o.post_failure_map));
            m.insert("post_failure_p99".into(), Json::Num(o.post_failure_p99));
            m.insert("final_devices".into(), Json::Num(o.final_devices as f64));
            m.insert("recovery_seconds".into(), finite_or_null(o.recovery_seconds));
            Json::Obj(m)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_load_ladder_autoscale_beats_stride_only() {
        let (_, outcomes) = step_load(7);
        let stride = &outcomes[0];
        let ladder_only = &outcomes[1];
        let auto = &outcomes[2];
        // Quality-aware degradation beats stride subsampling at 2×
        // overload, and the closed loop beats both (it buys capacity
        // back and climbs the ladder mid-overload).
        assert!(
            ladder_only.overload_map > stride.overload_map + 0.10,
            "ladder {:.3} vs stride {:.3}",
            ladder_only.overload_map,
            stride.overload_map
        );
        assert!(
            auto.overload_map > ladder_only.overload_map + 0.05,
            "autoscale {:.3} vs ladder {:.3}",
            auto.overload_map,
            ladder_only.overload_map
        );
        // The controller actually scaled: devices ramp past the static 4.
        assert!(auto.peak_devices >= 8, "peak {}", auto.peak_devices);
        assert!(auto.control_actions > 0);
    }

    #[test]
    fn diurnal_devices_track_load_both_ways() {
        let (_, points, out) = diurnal(11);
        assert_eq!(points.len(), 4);
        // Morning adds devices over night; peak adds more; night again
        // sheds them.
        assert!(points[1].devices > points[0].devices, "{points:?}");
        assert!(points[2].devices > points[1].devices, "{points:?}");
        assert!(points[3].devices < points[2].devices, "{points:?}");
        assert!(out.controller_device_actions() >= 4);
    }

    #[test]
    fn device_failure_controller_recovers_capacity() {
        let (_, outcomes) = device_failure(13);
        let frozen = &outcomes[0];
        let auto = &outcomes[1];
        assert!(auto.recovery_seconds.is_finite(), "{auto:?}");
        assert!(auto.recovery_seconds < 30.0, "{auto:?}");
        assert!(
            auto.post_failure_map > frozen.post_failure_map + 0.03,
            "auto {:.3} vs frozen {:.3}",
            auto.post_failure_map,
            frozen.post_failure_map
        );
        assert!(auto.final_devices >= 9, "{auto:?}");
    }

    #[test]
    fn analysis_helpers_basic_shapes() {
        let ladder = eth_ladder();
        // Empty inputs are zeros, not panics.
        assert_eq!(delivered_map(&[], &ladder, (0.0, 10.0)), 0.0);
        assert_eq!(windowed_p99(&[], (0.0, 10.0)), 0.0);
        assert_eq!(rung_recovery_seconds(&[], 10.0), 0.0);
    }

    #[test]
    fn json_bundle_reparses_and_respects_scenario_selection() {
        let j = autoscale_json(5, "all").expect("known scenario");
        let text = j.to_string();
        let back = Json::parse(&text).expect("autoscale JSON must reparse");
        assert_eq!(back.get("seed").and_then(Json::as_i64), Some(5));
        assert_eq!(back.get("step_load").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(back.get("diurnal").unwrap().as_arr().unwrap().len(), 4);
        assert_eq!(
            back.get("device_failure").unwrap().as_arr().unwrap().len(),
            2
        );
        // A single scenario emits only its own section.
        let step_only = autoscale_json(5, "step").expect("known scenario");
        assert!(step_only.get("step_load").is_some());
        assert!(step_only.get("diurnal").is_none());
        assert!(step_only.get("device_failure").is_none());
        // Unknown scenarios are an error, not an empty success.
        assert!(autoscale_json(5, "bogus").is_none());
    }
}
