//! Tables I, II, III, VIII: configuration registries, rendered in the
//! paper's layouts (these are the setup tables; the numbers are the
//! calibrated constants the dynamic experiments consume).

use crate::device::link::LinkProfile;
use crate::device::{DetectorModelId, DeviceKind};
use crate::util::table::Table;
use crate::video::presets;

/// Table I: the two test videos.
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table I: Two Test Videos (synthetic analogs, DESIGN.md §3)",
        &["Video Name", "ADL-Rundle-6", "ETH-Sunnyday"],
    );
    let adl = presets::adl_rundle6(0);
    let eth = presets::eth_sunnyday(0);
    t.row(vec![
        "Video FPS".into(),
        format!("{}", adl.fps),
        format!("{}", eth.fps),
    ]);
    t.row(vec![
        "#Frames".into(),
        format!("{}", adl.num_frames),
        format!("{}", eth.num_frames),
    ]);
    t.row(vec![
        "Resolution".into(),
        format!("{}x{}", adl.width, adl.height),
        format!("{}x{}", eth.width, eth.height),
    ]);
    t.row(vec![
        "Camera".into(),
        "static".into(),
        "moving".into(),
    ]);
    t
}

/// Table II: the two object detection models (paper-scale profiles).
pub fn table2() -> Table {
    let mut t = Table::new(
        "Table II: Two Object Detection Models",
        &["Model", "Backbone", "Input Size", "Model Size", "Data Type"],
    );
    for m in [DetectorModelId::Ssd300, DetectorModelId::Yolov3] {
        t.row(vec![
            m.label().to_string(),
            m.backbone().to_string(),
            format!("{0}x{0}x3", m.input_size()),
            format!("{}MB", m.model_size_mb()),
            "FP16".into(),
        ]);
    }
    t
}

/// Table II-bis: the TinyDet stand-ins actually served via PJRT, read
/// from the artifact manifest when available.
pub fn table2_tinydet(artifact_dir: &std::path::Path) -> Option<Table> {
    let manifest = crate::runtime::load_manifest(artifact_dir).ok()?;
    let mut t = Table::new(
        "TinyDet variants (PJRT-served stand-ins)",
        &["Model", "Input", "Grid", "Params", "MFLOPs/frame"],
    );
    for m in &manifest.models {
        t.row(vec![
            m.name.clone(),
            format!("{0}x{0}x3", m.input_size),
            format!("{0}x{0}", m.grid),
            format!("{}", m.params),
            format!("{:.1}", m.flops_per_frame as f64 / 1e6),
        ]);
    }
    Some(t)
}

/// Table III: edge server configurations.
pub fn table3() -> Table {
    let mut t = Table::new(
        "Table III: Edge Server Configuration",
        &["Edge Server", "Fast", "Slow"],
    );
    t.row(vec!["CPU".into(), "Intel i7-10700K".into(), "AMD A6-9225".into()]);
    t.row(vec!["CPU Frequency".into(), "3.8GHz".into(), "2.6GHz".into()]);
    t.row(vec!["CPU #Cores".into(), "8".into(), "2".into()]);
    t.row(vec!["Main Memory Size".into(), "24GB".into(), "12GB".into()]);
    t.row(vec![
        "TDP (model)".into(),
        format!("{}W", DeviceKind::FastCpu.tdp_watts()),
        format!("{}W", DeviceKind::SlowCpu.tdp_watts()),
    ]);
    t
}

/// Table VIII: connection-interface bandwidths.
pub fn table8() -> Table {
    let mut t = Table::new(
        "Table VIII: Comparison of Bandwidth for Different Interfaces",
        &["Port", "Nominal Bandwidth", "Modelled Effective"],
    );
    for link in LinkProfile::registry() {
        t.row(vec![
            link.name.to_string(),
            format!("{:.1} Gbps", link.nominal_bps / 1e9),
            format!("{:.2} Gbps", link.effective_bps() / 1e9),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_paper_values() {
        let r = table1().render();
        assert!(r.contains("30") && r.contains("14"));
        assert!(r.contains("525") && r.contains("354"));
        assert!(r.contains("1920x1080") && r.contains("640x480"));
    }

    #[test]
    fn table2_has_both_models() {
        let r = table2().render();
        assert!(r.contains("SSD300") && r.contains("YOLOv3"));
        assert!(r.contains("VGG-16") && r.contains("DarkNet-53"));
        assert!(r.contains("51MB") && r.contains("119MB"));
    }

    #[test]
    fn table8_has_all_links() {
        let r = table8().render();
        for name in ["USB 2.0", "USB 3.0", "Ethernet", "WiFi 6", "4G", "5G"] {
            assert!(r.contains(name), "{name}");
        }
    }
}
