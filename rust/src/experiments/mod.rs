//! Experiment drivers: one function per paper table/figure.
//!
//! Shared by the bench binaries (`benches/table*.rs`) and the `eva`
//! CLI (`eva table --id ...`). Each driver returns both a rendered
//! [`crate::util::table::Table`] and the structured numbers, so benches
//! can assert the paper's *shape* (who wins, scaling slope, crossover
//! points) against the measured values.

pub mod autoscale;
pub mod churn;
pub mod common;
pub mod configs;
pub mod parallel;
pub mod sched;
pub mod links;
pub mod lang;
pub mod energy;
pub mod dropping;
pub mod fleet;
pub mod forecast;
pub mod gate;
pub mod scale;
pub mod shard;
pub mod telemetry;
pub mod transport;

pub use common::{online_map, saturated_fps, zero_drop_baseline, CellOutcome};
