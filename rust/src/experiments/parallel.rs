//! Tables IV & V + Figure 5: parallel detection with n NCS2 sticks.

use crate::coordinator::SchedulerKind;
use crate::device::link::LinkProfile;
use crate::device::{DetectorModelId, Fleet};
use crate::experiments::common::{online_map, saturated_fps, zero_drop_baseline};
use crate::util::table::{f, pct, Table};
use crate::video::{generate, presets, ClipSpec};

/// Structured results for one model row-pair of Table IV/V.
#[derive(Debug, Clone)]
pub struct ParallelSweep {
    pub model: DetectorModelId,
    /// Zero-drop baseline (μ, mAP).
    pub baseline: (f64, f64),
    /// Online single-device mAP (with dropping).
    pub single_map: f64,
    /// (n, σ_P, mAP) for n = 1..=max_n.
    pub by_n: Vec<(usize, f64, f64)>,
}

/// Run the Table IV/V sweep for one model on one video preset.
pub fn sweep(spec: &ClipSpec, model: DetectorModelId, max_n: usize, seed: u64) -> ParallelSweep {
    let clip = generate(spec, None);
    let baseline = zero_drop_baseline(&clip, model, seed ^ 0xBA5E);
    let mut by_n = Vec::with_capacity(max_n);
    let mut single_map = 0.0;
    for n in 1..=max_n {
        let fleet = Fleet::ncs2_sticks(n, model, LinkProfile::usb3());
        let fps = saturated_fps(&clip, &fleet, SchedulerKind::Fcfs, seed + n as u64);
        let (map, _) = online_map(&clip, &fleet, SchedulerKind::Fcfs, seed + 100 + n as u64);
        if n == 1 {
            single_map = map;
        }
        by_n.push((n, fps, map));
    }
    ParallelSweep {
        model,
        baseline,
        single_map,
        by_n,
    }
}

/// Render the sweeps in the paper's Table IV/V layout.
pub fn render(title: &str, sweeps: &[ParallelSweep]) -> Table {
    let max_n = sweeps.iter().map(|s| s.by_n.len()).max().unwrap_or(0);
    let mut header: Vec<String> = vec!["Model".into(), "Metric".into(), "ZeroDrop".into(), "Single".into()];
    for n in 2..=max_n {
        header.push(format!("n={n}"));
    }
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(title, &hdr);
    for s in sweeps {
        let mut fps_row = vec![
            s.model.label().to_string(),
            "Detection FPS".to_string(),
            f(s.baseline.0, 1),
            f(s.by_n[0].1, 1),
        ];
        let mut map_row = vec![
            s.model.label().to_string(),
            "mAP (%)".to_string(),
            pct(s.baseline.1),
            pct(s.single_map),
        ];
        for (_, fps, map) in s.by_n.iter().skip(1) {
            fps_row.push(f(*fps, 1));
            map_row.push(pct(*map));
        }
        t.row(fps_row);
        t.row(map_row);
    }
    t
}

/// Table IV: ETH-Sunnyday.
pub fn table4(seed: u64) -> (Table, Vec<ParallelSweep>) {
    let spec = presets::eth_sunnyday(seed);
    let sweeps = vec![
        sweep(&spec, DetectorModelId::Ssd300, 7, seed + 1),
        sweep(&spec, DetectorModelId::Yolov3, 7, seed + 2),
    ];
    (
        render(
            "Table IV: Parallel Detection using Multiple NCS2 Sticks (ETH-Sunnyday)",
            &sweeps,
        ),
        sweeps,
    )
}

/// Table V: ADL-Rundle-6.
pub fn table5(seed: u64) -> (Table, Vec<ParallelSweep>) {
    let spec = presets::adl_rundle6(seed);
    let sweeps = vec![
        sweep(&spec, DetectorModelId::Ssd300, 7, seed + 1),
        sweep(&spec, DetectorModelId::Yolov3, 7, seed + 2),
    ];
    (
        render(
            "Table V: Parallel Detection using Multiple NCS2 Sticks (ADL-Rundle-6)",
            &sweeps,
        ),
        sweeps,
    )
}

/// Figure 5: FPS + mAP trend vs n on ADL-Rundle-6, as CSV series.
pub fn fig5(seed: u64) -> (Table, Vec<ParallelSweep>) {
    let (_, sweeps) = table5(seed);
    let mut t = Table::new(
        "Figure 5: Detection FPS and mAP vs #NCS2 (ADL-Rundle-6)",
        &["n", "SSD FPS", "SSD mAP%", "YOLO FPS", "YOLO mAP%"],
    );
    let (ssd, yolo) = (&sweeps[0], &sweeps[1]);
    for i in 0..ssd.by_n.len() {
        t.row(vec![
            format!("{}", i + 1),
            f(ssd.by_n[i].1, 1),
            pct(ssd.by_n[i].2),
            f(yolo.by_n[i].1, 1),
            pct(yolo.by_n[i].2),
        ]);
    }
    (t, sweeps)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_shape_holds() {
        // Keep the sweep small in unit tests (n ≤ 4): full grids run in
        // the bench binaries.
        let spec = presets::eth_sunnyday(3);
        let s = sweep(&spec, DetectorModelId::Yolov3, 4, 7);
        // (1) near-linear FPS scaling.
        for (n, fps, _) in &s.by_n {
            let ideal = 2.5 * *n as f64;
            assert!((fps - ideal).abs() / ideal < 0.1, "n={n} fps={fps}");
        }
        // (2) single-device online mAP well below the zero-drop baseline.
        assert!(s.single_map + 0.08 < s.baseline.1);
        // (3) mAP recovers monotonically-ish with n.
        assert!(s.by_n[3].2 > s.by_n[0].2 + 0.05);
    }

    #[test]
    fn render_layout() {
        let spec = presets::eth_sunnyday(4);
        let s = sweep(&spec, DetectorModelId::Ssd300, 2, 9);
        let t = render("T", &[s]);
        assert_eq!(t.rows.len(), 2);
        assert!(t.render().contains("SSD300"));
    }
}
