//! Fleet scaling experiments: streams × devices sweeps in virtual time.
//!
//! Two sweeps share one fixed offered load (8 streams):
//!
//! * [`scaling`] — admission **enforced**: shows the control plane
//!   trading streams for latency as the pool grows (admit/degrade/reject
//!   counts, bounded p99, fairness).
//! * [`saturation_sweep`] — admission off, big windows: measures raw
//!   work-conserving capacity; aggregate σ tracks Σμᵢ until the pool
//!   outgrows the offered load.

use crate::device::{DetectorModelId, DeviceInstance, DeviceKind};
use crate::fleet::admission::{AdmissionPolicy, Decision};
use crate::fleet::metrics::FleetReport;
use crate::fleet::sim::{run_fleet, Scenario};
use crate::fleet::stream::StreamSpec;
use crate::util::table::{f, Table};

/// One row of a streams × devices sweep.
#[derive(Debug, Clone, Copy)]
pub struct ScalePoint {
    pub devices: usize,
    pub streams: usize,
    /// Ideal pool capacity Σμᵢ.
    pub ideal_rate: f64,
    /// Measured aggregate processed FPS.
    pub aggregate_fps: f64,
    pub admitted: usize,
    pub degraded: usize,
    pub rejected: usize,
    /// Mean over admitted streams' p99 output latency (seconds).
    pub mean_p99: f64,
    /// Jain fairness index over admitted streams.
    pub fairness: f64,
}

/// `n` uniform-rate pool devices (NCS2-class unless overridden).
pub fn pool_of(n: usize, rate: f64) -> Vec<DeviceInstance> {
    (0..n)
        .map(|i| DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, i, rate))
        .collect()
}

fn uniform_streams(n: usize, fps: f64, frames: u64, window: usize) -> Vec<StreamSpec> {
    (0..n)
        .map(|i| StreamSpec::new(&format!("s{i}"), fps, frames).with_window(window))
        .collect()
}

fn point(report: &FleetReport, devices: usize, streams: usize, ideal: f64) -> ScalePoint {
    let mut admitted = 0;
    let mut degraded = 0;
    let mut rejected = 0;
    let mut p99_sum = 0.0;
    let mut p99_n = 0usize;
    for s in report.streams.iter() {
        match s.decision {
            Decision::Admit { .. } => admitted += 1,
            Decision::Degrade { .. } | Decision::SwapModel { .. } => {
                admitted += 1;
                degraded += 1;
            }
            Decision::Reject => rejected += 1,
        }
        if s.decision.is_admitted() {
            p99_sum += s.metrics.latency.p99();
            p99_n += 1;
        }
    }
    ScalePoint {
        devices,
        streams,
        ideal_rate: ideal,
        aggregate_fps: report.aggregate_fps(),
        admitted,
        degraded,
        rejected,
        mean_p99: if p99_n == 0 { 0.0 } else { p99_sum / p99_n as f64 },
        fairness: report.fairness(),
    }
}

/// Admission-enforced sweep: 8 × 5-FPS streams vs growing pools of
/// 2.5-FPS devices.
pub fn scaling(seed: u64) -> (Table, Vec<ScalePoint>) {
    let streams = 8usize;
    let fps = 5.0;
    let frames = 300u64;
    let mut t = Table::new(
        "Fleet scaling with admission (8 streams × 5 FPS vs m × 2.5-FPS devices)",
        &[
            "devices", "Σμ", "aggregate σ", "admit", "degrade", "reject",
            "mean p99 (s)", "Jain",
        ],
    );
    // 2.5 × 20 × 0.95 = 47.5 ≥ offered 40: the largest pool fits every
    // stream at full rate.
    let mut points = Vec::new();
    for m in [1usize, 2, 4, 8, 12, 20] {
        let scenario = Scenario::new(
            pool_of(m, 2.5),
            uniform_streams(streams, fps, frames, 4),
        )
        .with_seed(seed ^ (m as u64));
        let report = run_fleet(&scenario);
        let p = point(&report, m, streams, 2.5 * m as f64);
        t.row(vec![
            format!("{m}"),
            f(p.ideal_rate, 1),
            f(p.aggregate_fps, 2),
            format!("{}", p.admitted),
            format!("{}", p.degraded),
            format!("{}", p.rejected),
            f(p.mean_p99, 2),
            f(p.fairness, 3),
        ]);
        points.push(p);
    }
    (t, points)
}

/// Raw-capacity sweep: admission off, windows large enough that the pool
/// never starves; aggregate σ should track min(Σμᵢ, offered λ).
pub fn saturation_sweep(seed: u64) -> (Table, Vec<ScalePoint>) {
    let streams = 8usize;
    let fps = 10.0; // offered 80 FPS, far above every pool below
    let frames = 300u64;
    let mut t = Table::new(
        "Fleet saturation (8 streams × 10 FPS, admission off): σ vs Σμ",
        &["devices", "Σμ", "aggregate σ", "σ / Σμ", "Jain"],
    );
    let mut points = Vec::new();
    for m in [1usize, 2, 3, 4, 6, 8] {
        let scenario = Scenario::new(
            pool_of(m, 2.5),
            uniform_streams(streams, fps, frames, 16),
        )
        .with_admission(AdmissionPolicy::admit_all())
        .with_seed(seed ^ (0x5CA1E0 + m as u64));
        let report = run_fleet(&scenario);
        let p = point(&report, m, streams, 2.5 * m as f64);
        t.row(vec![
            format!("{m}"),
            f(p.ideal_rate, 1),
            f(p.aggregate_fps, 2),
            f(p.aggregate_fps / p.ideal_rate, 3),
            f(p.fairness, 3),
        ]);
        points.push(p);
    }
    (t, points)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn saturation_tracks_pool_rate() {
        let (_, points) = saturation_sweep(21);
        for p in &points {
            let ratio = p.aggregate_fps / p.ideal_rate;
            assert!(
                (ratio - 1.0).abs() < 0.12,
                "m={}: σ {} vs Σμ {}",
                p.devices,
                p.aggregate_fps,
                p.ideal_rate
            );
        }
        // Monotone in pool size.
        for w in points.windows(2) {
            assert!(w[1].aggregate_fps > w[0].aggregate_fps);
        }
    }

    #[test]
    fn admission_relaxes_as_pool_grows() {
        let (_, points) = scaling(22);
        // Tiny pool rejects someone; big pool admits everyone at full rate.
        assert!(points[0].rejected > 0, "{:?}", points[0]);
        let last = points[points.len() - 1];
        assert_eq!(last.rejected, 0, "{last:?}");
        assert_eq!(last.degraded, 0, "{last:?}");
        // Admitted count never shrinks as devices are added.
        for w in points.windows(2) {
            assert!(w[1].admitted >= w[0].admitted);
        }
    }
}
