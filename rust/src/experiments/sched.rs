//! Table VII: impact of scheduling algorithms (RR vs FCFS) on
//! homogeneous and heterogeneous fleets — plus an ablation over all four
//! schedulers including the paper's proposed performance-aware
//! proportional scheduler.

use crate::coordinator::SchedulerKind;
use crate::device::link::LinkProfile;
use crate::device::{DetectorModelId, DeviceKind, Fleet};
use crate::experiments::common::saturated_fps;
use crate::util::table::{f, Table};
use crate::video::{generate, presets};

/// The three fleet families of Table VII.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetFamily {
    Ncs2Only,
    FastCpuPlusNcs2,
    SlowCpuPlusNcs2,
}

impl FleetFamily {
    pub fn label(&self) -> &'static str {
        match self {
            FleetFamily::Ncs2Only => "NCS2 Only",
            FleetFamily::FastCpuPlusNcs2 => "Fast CPU + NCS2",
            FleetFamily::SlowCpuPlusNcs2 => "Slow CPU + NCS2",
        }
    }

    /// Build the fleet with `n` sticks (n = 0 -> CPU only; `None` for
    /// NCS2-only with n = 0, which is an empty fleet).
    pub fn fleet(&self, n: usize, model: DetectorModelId) -> Option<Fleet> {
        let hub = LinkProfile::usb3();
        match self {
            FleetFamily::Ncs2Only => {
                if n == 0 {
                    None
                } else {
                    Some(Fleet::ncs2_sticks(n, model, hub))
                }
            }
            FleetFamily::FastCpuPlusNcs2 => Some(Fleet::cpu_plus_sticks(
                DeviceKind::FastCpu,
                n,
                model,
                hub,
            )),
            FleetFamily::SlowCpuPlusNcs2 => Some(Fleet::cpu_plus_sticks(
                DeviceKind::SlowCpu,
                n,
                model,
                hub,
            )),
        }
    }
}

/// Structured Table VII results: fps[scheduler][family][n] (n = 0..=max_n).
#[derive(Debug, Clone)]
pub struct SchedSweep {
    pub scheduler: SchedulerKind,
    pub family: FleetFamily,
    /// (n_sticks, σ_P); `None` capacity when the fleet is empty.
    pub by_n: Vec<(usize, Option<f64>)>,
}

/// Run one (scheduler, family) row of Table VII.
pub fn sweep_row(
    scheduler: SchedulerKind,
    family: FleetFamily,
    max_n: usize,
    seed: u64,
) -> SchedSweep {
    let clip = generate(&presets::eth_sunnyday(seed), None);
    let model = DetectorModelId::Yolov3;
    let mut by_n = Vec::with_capacity(max_n + 1);
    for n in 0..=max_n {
        let fps = family
            .fleet(n, model)
            .map(|fleet| saturated_fps(&clip, &fleet, scheduler, seed + n as u64));
        by_n.push((n, fps));
    }
    SchedSweep {
        scheduler,
        family,
        by_n,
    }
}

/// Full Table VII (RR + FCFS × three families).
pub fn table7(seed: u64) -> (Table, Vec<SchedSweep>) {
    let mut header = vec!["Scheduler".to_string(), "Fleet".to_string()];
    for n in 0..=7 {
        header.push(format!("{n}"));
    }
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "Table VII: RR and FCFS Schedulers (ETH-Sunnyday, YOLOv3) — Detection FPS vs #NCS2",
        &hdr,
    );
    let mut sweeps = Vec::new();
    for (si, scheduler) in [SchedulerKind::RoundRobin, SchedulerKind::Fcfs]
        .into_iter()
        .enumerate()
    {
        for (fi, family) in [
            FleetFamily::Ncs2Only,
            FleetFamily::FastCpuPlusNcs2,
            FleetFamily::SlowCpuPlusNcs2,
        ]
        .into_iter()
        .enumerate()
        {
            let s = sweep_row(scheduler, family, 7, seed + (si * 10 + fi) as u64);
            let mut row = vec![scheduler.label().to_string(), family.label().to_string()];
            for (_, fps) in &s.by_n {
                row.push(match fps {
                    Some(v) => f(*v, 1),
                    None => "-".to_string(),
                });
            }
            t.row(row);
            sweeps.push(s);
        }
    }
    (t, sweeps)
}

/// Ablation (beyond the paper): all four schedulers on the heterogeneous
/// fast-CPU fleet, showing WRR/proportional recovering most of FCFS's win.
pub fn scheduler_ablation(seed: u64) -> (Table, Vec<(SchedulerKind, f64)>) {
    let clip = generate(&presets::eth_sunnyday(seed), None);
    let fleet = FleetFamily::FastCpuPlusNcs2
        .fleet(7, DetectorModelId::Yolov3)
        .unwrap();
    let mut t = Table::new(
        "Ablation: all schedulers (Fast CPU + 7 NCS2, YOLOv3, ETH-Sunnyday)",
        &["Scheduler", "Detection FPS"],
    );
    let mut results = Vec::new();
    for scheduler in [
        SchedulerKind::RoundRobin,
        SchedulerKind::WeightedRoundRobin,
        SchedulerKind::Proportional,
        SchedulerKind::Fcfs,
    ] {
        let fps = saturated_fps(&clip, &fleet, scheduler, seed + 5);
        t.row(vec![scheduler.label().to_string(), f(fps, 1)]);
        results.push((scheduler, fps));
    }
    (t, results)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fcfs_beats_rr_on_fast_cpu_fleet() {
        let rr = sweep_row(SchedulerKind::RoundRobin, FleetFamily::FastCpuPlusNcs2, 3, 1);
        let fcfs = sweep_row(SchedulerKind::Fcfs, FleetFamily::FastCpuPlusNcs2, 3, 1);
        for n in 1..=3 {
            let rr_fps = rr.by_n[n].1.unwrap();
            let fcfs_fps = fcfs.by_n[n].1.unwrap();
            assert!(
                fcfs_fps > rr_fps + 2.0,
                "n={n}: fcfs {fcfs_fps} rr {rr_fps}"
            );
        }
    }

    #[test]
    fn rr_hurt_by_slow_straggler() {
        // Paper: slow CPU + sticks under RR ≈ 0.9..3.4 (collapse);
        // FCFS ≈ sticks + 0.4.
        let rr = sweep_row(SchedulerKind::RoundRobin, FleetFamily::SlowCpuPlusNcs2, 2, 2);
        let fcfs = sweep_row(SchedulerKind::Fcfs, FleetFamily::SlowCpuPlusNcs2, 2, 2);
        let rr1 = rr.by_n[1].1.unwrap();
        let fcfs1 = fcfs.by_n[1].1.unwrap();
        assert!((rr1 - 0.8).abs() < 0.3, "rr n=1 {rr1} (paper 0.9)");
        assert!((fcfs1 - 2.9).abs() < 0.4, "fcfs n=1 {fcfs1} (paper 3.0)");
    }

    #[test]
    fn cpu_only_column() {
        let s = sweep_row(SchedulerKind::Fcfs, FleetFamily::FastCpuPlusNcs2, 0, 3);
        let cpu_only = s.by_n[0].1.unwrap();
        assert!((cpu_only - 13.5).abs() < 0.5, "{cpu_only}");
        let none = sweep_row(SchedulerKind::Fcfs, FleetFamily::Ncs2Only, 0, 3);
        assert!(none.by_n[0].1.is_none());
    }

    #[test]
    fn ablation_orders_schedulers() {
        let (_, results) = scheduler_ablation(4);
        let get = |k: SchedulerKind| results.iter().find(|(s, _)| *s == k).unwrap().1;
        // FCFS (work-conserving) ≥ WRR/prop (weighted rounds) > RR (barrier).
        assert!(get(SchedulerKind::Fcfs) >= get(SchedulerKind::WeightedRoundRobin) - 1.0);
        assert!(get(SchedulerKind::WeightedRoundRobin) > get(SchedulerKind::RoundRobin) + 2.0);
        assert!(get(SchedulerKind::Proportional) > get(SchedulerKind::RoundRobin) + 2.0);
    }
}
