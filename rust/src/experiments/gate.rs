//! Gate sweeps: per-frame motion-gated detection vs always-detect,
//! across content-dynamics presets (see EXPERIMENTS.md §Gate).
//!
//! Each preset runs one stream against a single device with 1.2×
//! headroom, twice — once detecting every frame, once behind
//! [`crate::gate::GatePolicy`] — and compares **effective per-device
//! FPS** (frames covered per second of device busy time) against
//! **delivered mAP** under the tracker-proxy staleness model:
//!
//! * `lobby` — near-static content; the gate skips most frames and the
//!   acceptance bar is ≥ 2× effective FPS at < 2% delivered-mAP cost.
//! * `highway` — sustained motion; the gate must stay out of the way.
//! * `sports` — high motion with hard scene cuts; every cut must force
//!   a fresh detection.
//!
//! Gate-skipped frames are charged a *stretched* staleness decay
//! ([`gated_delivered_map`]): the skip was deliberate — the
//! constant-velocity tracker proxy extrapolates boxes over known-quiet
//! content — unlike overload drops, whose reuse age decays at the full
//! [`staleness_factor`] rate.

use std::collections::{BTreeMap, BTreeSet};

use crate::autoscale::ladder::{staleness_factor, ModelLadder};
use crate::control::{WireEvent, WirePayload};
use crate::experiments::fleet::pool_of;
use crate::fleet::admission::{AdmissionMode, AdmissionPolicy, DegradeMode};
use crate::fleet::metrics::StreamReport;
use crate::fleet::sim::{run_fleet_with, FleetRunOutput, Scenario};
use crate::fleet::stream::StreamSpec;
use crate::gate::{GateConfig, GateVerdict, MotionDynamics};
use crate::util::json::Json;
use crate::util::table::{f, Table};

/// One content-dynamics preset: the virtual-time twin of the
/// [`crate::video::presets`] clip of the same name (same FPS and frame
/// count; the pixel clip feeds the wall-clock path, the
/// [`MotionDynamics`] model feeds this one).
#[derive(Debug, Clone)]
pub struct ContentPreset {
    pub name: &'static str,
    pub fps: f64,
    pub frames: u64,
    pub dynamics: MotionDynamics,
}

/// The three content presets, quietest first.
pub fn content_presets() -> Vec<ContentPreset> {
    vec![
        ContentPreset {
            name: "lobby",
            fps: 15.0,
            frames: 450,
            dynamics: MotionDynamics::lobby(),
        },
        ContentPreset {
            name: "highway",
            fps: 25.0,
            frames: 500,
            dynamics: MotionDynamics::highway(),
        },
        ContentPreset {
            name: "sports",
            fps: 30.0,
            frames: 600,
            dynamics: MotionDynamics::sports(),
        },
    ]
}

/// Delivered mAP with the gate's tracker proxy: like
/// [`crate::experiments::autoscale::delivered_map`], but a record whose
/// frame was *gate-skipped* (as opposed to overload-dropped) decays at
/// `age / stretch` — the constant-velocity extrapolation holds up far
/// better over content the gate measured as quiet — and a frame the
/// gate down-runged is charged that rung's quality.
pub fn gated_delivered_map(
    streams: &[StreamReport],
    ladder: &ModelLadder,
    window: (f64, f64),
    gate_log: &[WireEvent],
    stretch: f64,
) -> f64 {
    let mut skipped: BTreeSet<(usize, u64)> = BTreeSet::new();
    let mut rungs: BTreeMap<(usize, u64), usize> = BTreeMap::new();
    for ev in gate_log {
        if let WirePayload::Gate { stream, frame, verdict } = ev.payload {
            match verdict {
                GateVerdict::Skip => {
                    skipped.insert((stream, frame));
                }
                GateVerdict::DownRung(r) => {
                    rungs.insert((stream, frame), r);
                }
                _ => {}
            }
        }
    }
    let quality = |s: &StreamReport, fid: u64, ts: f64| {
        let rung = rungs.get(&(s.id, fid)).copied().unwrap_or_else(|| s.rung_at(ts));
        ladder.quality(rung)
    };

    let (lo, hi) = window;
    let mut total = 0.0;
    let mut n = 0usize;
    for s in streams {
        for rec in &s.records {
            if rec.capture_ts < lo || rec.capture_ts >= hi {
                continue;
            }
            n += 1;
            match rec.stale_from {
                None => total += quality(s, rec.frame_id, rec.capture_ts),
                Some(src) if src == rec.frame_id => {} // nothing reused
                Some(src) => {
                    let src_rec = &s.records[src as usize];
                    let mut age = (rec.capture_ts - src_rec.capture_ts).max(0.0);
                    if skipped.contains(&(s.id, rec.frame_id)) {
                        age /= stretch.max(1.0);
                    }
                    total += quality(s, src_rec.frame_id, src_rec.capture_ts)
                        * staleness_factor(age);
                }
            }
        }
    }
    if n == 0 {
        0.0
    } else {
        total / n as f64
    }
}

/// One (preset, mode) cell of the content sweep.
#[derive(Debug, Clone)]
pub struct GateOutcome {
    pub preset: &'static str,
    /// `"always-detect"` or `"gated"`.
    pub mode: &'static str,
    /// Frames covered (fresh detection or stale fill with a real
    /// source) per second of stream time.
    pub delivered_fps: f64,
    /// Frames covered per second of device *busy* time — the paper's
    /// effective per-device throughput; skipping quiet frames raises it
    /// without buying hardware.
    pub effective_device_fps: f64,
    /// Delivered mAP under the tracker-proxy staleness model.
    pub delivered_map: f64,
    /// Fraction of offered frames that ran a detector.
    pub detect_fraction: f64,
    /// Gate `Skip` verdicts.
    pub skips: u64,
    /// Forced refreshes: `SkipCap` + `SceneCut` verdicts.
    pub refreshes: u64,
    /// Gate `DownRung` verdicts (budget pressure).
    pub downrungs: u64,
}

fn eth_ladder() -> ModelLadder {
    ModelLadder::from_profiles("eth_sunnyday")
}

/// Admit-all policy carrying the model ladder, so gate down-rungs map
/// to real speedups (under stride-mode admission they would be logged
/// but speed-neutral).
fn gate_admission(ladder: &ModelLadder) -> AdmissionPolicy {
    AdmissionPolicy {
        mode: AdmissionMode::AdmitAll,
        degrade: DegradeMode::ModelSwap {
            speedups: ladder.speedups(),
        },
        ..AdmissionPolicy::default()
    }
}

fn preset_run(
    p: &ContentPreset,
    gate: Option<GateConfig>,
    seed: u64,
    traced: bool,
) -> FleetRunOutput {
    let streams = vec![StreamSpec::new(p.name, p.fps, p.frames).with_window(4)];
    // One device with 1.2× headroom: always-detect keeps up, so the
    // sweep isolates what gating buys beyond overload shedding.
    let mut scenario = Scenario::new(pool_of(1, p.fps * 1.2), streams)
        .with_admission(gate_admission(&eth_ladder()))
        .with_seed(seed);
    if let Some(cfg) = gate {
        scenario = scenario.with_gate(cfg);
    }
    if traced {
        scenario = scenario.with_telemetry();
    }
    run_fleet_with(&scenario, None)
}

/// One preset's gated cell re-run with span tracing on (the `eva gate
/// --metrics-out`/`--trace-out` surface); `None` for unknown presets.
pub fn traced_gated_run(preset: &str, seed: u64) -> Option<FleetRunOutput> {
    let p = content_presets().into_iter().find(|p| p.name == preset)?;
    let cfg = GateConfig::for_dynamics(p.dynamics.clone());
    Some(preset_run(&p, Some(cfg), seed, true))
}

fn outcome(
    p: &ContentPreset,
    mode: &'static str,
    out: &FleetRunOutput,
    ladder: &ModelLadder,
    stretch: f64,
) -> GateOutcome {
    let report = &out.report;
    let duration = p.frames as f64 / p.fps;
    let covered: usize = report
        .streams
        .iter()
        .map(|s| {
            s.records
                .iter()
                .filter(|r| r.stale_from != Some(r.frame_id))
                .count()
        })
        .sum();
    let busy: f64 = report.device_busy.iter().sum();
    let (mut skips, mut refreshes, mut downrungs) = (0u64, 0u64, 0u64);
    for ev in &out.gate_log {
        if let WirePayload::Gate { verdict, .. } = ev.payload {
            match verdict {
                GateVerdict::Skip => skips += 1,
                GateVerdict::SkipCap | GateVerdict::SceneCut => refreshes += 1,
                GateVerdict::DownRung(_) => downrungs += 1,
                GateVerdict::Detect => {}
            }
        }
    }
    let total = report.total_frames();
    GateOutcome {
        preset: p.name,
        mode,
        delivered_fps: covered as f64 / duration,
        effective_device_fps: if busy > 0.0 { covered as f64 / busy } else { 0.0 },
        delivered_map: gated_delivered_map(
            &report.streams,
            ladder,
            (0.0, f64::INFINITY),
            &out.gate_log,
            stretch,
        ),
        detect_fraction: if total == 0 {
            0.0
        } else {
            report.total_processed() as f64 / total as f64
        },
        skips,
        refreshes,
        downrungs,
    }
}

fn preset_pair(p: &ContentPreset, seed: u64, ladder: &ModelLadder) -> [GateOutcome; 2] {
    let cfg = GateConfig::for_dynamics(p.dynamics.clone());
    let stretch = cfg.tracker_stretch;
    let plain = preset_run(p, None, seed, false);
    let gated = preset_run(p, Some(cfg), seed, false);
    [
        outcome(p, "always-detect", &plain, ladder, stretch),
        outcome(p, "gated", &gated, ladder, stretch),
    ]
}

/// The acceptance sweep: every content preset, gated vs always-detect.
pub fn content_sweep(seed: u64) -> (Table, Vec<GateOutcome>) {
    let ladder = eth_ladder();
    let mut outcomes = Vec::new();
    for p in content_presets() {
        outcomes.extend(preset_pair(&p, seed, &ladder));
    }
    let mut t = Table::new(
        "Motion gate vs always-detect: effective device FPS against delivered mAP",
        &[
            "preset", "mode", "delivered σ", "device eff (FPS)", "mAP", "detect %",
            "skips", "refreshes", "down-rungs",
        ],
    );
    for o in &outcomes {
        t.row(vec![
            o.preset.to_string(),
            o.mode.to_string(),
            f(o.delivered_fps, 1),
            f(o.effective_device_fps, 1),
            f(o.delivered_map * 100.0, 1),
            f(o.detect_fraction * 100.0, 1),
            format!("{}", o.skips),
            format!("{}", o.refreshes),
            format!("{}", o.downrungs),
        ]);
    }
    (t, outcomes)
}

/// Machine-readable sweep results (the `--json` surface of `eva gate`):
/// only the requested preset is run and emitted (`"all"` runs all
/// three). `None` for an unknown preset name.
pub fn gate_json(seed: u64, scenario: &str) -> Option<Json> {
    if !matches!(scenario, "lobby" | "highway" | "sports" | "all") {
        return None;
    }
    let ladder = eth_ladder();
    let mut root = BTreeMap::new();
    root.insert("seed".into(), Json::Num(seed as f64));
    for p in content_presets() {
        if scenario != "all" && scenario != p.name {
            continue;
        }
        let pair = preset_pair(&p, seed, &ladder);
        root.insert(
            p.name.to_string(),
            Json::Arr(pair.iter().map(outcome_json).collect()),
        );
    }
    Some(Json::Obj(root))
}

fn outcome_json(o: &GateOutcome) -> Json {
    let mut m = BTreeMap::new();
    m.insert("mode".into(), Json::Str(o.mode.to_string()));
    m.insert("delivered_fps".into(), Json::Num(o.delivered_fps));
    m.insert(
        "effective_device_fps".into(),
        Json::Num(o.effective_device_fps),
    );
    m.insert("delivered_map".into(), Json::Num(o.delivered_map));
    m.insert("detect_fraction".into(), Json::Num(o.detect_fraction));
    m.insert("skips".into(), Json::Num(o.skips as f64));
    m.insert("refreshes".into(), Json::Num(o.refreshes as f64));
    m.insert("downrungs".into(), Json::Num(o.downrungs as f64));
    Json::Obj(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::autoscale::delivered_map;

    fn pair_for<'a>(
        outcomes: &'a [GateOutcome],
        preset: &str,
    ) -> (&'a GateOutcome, &'a GateOutcome) {
        let plain = outcomes
            .iter()
            .find(|o| o.preset == preset && o.mode == "always-detect")
            .expect("always-detect cell");
        let gated = outcomes
            .iter()
            .find(|o| o.preset == preset && o.mode == "gated")
            .expect("gated cell");
        (plain, gated)
    }

    #[test]
    fn lobby_gate_doubles_effective_fps_under_two_percent_map_cost() {
        let (_, outcomes) = content_sweep(7);
        let (plain, gated) = pair_for(&outcomes, "lobby");
        // The acceptance bar: ≥ 2× effective per-device FPS...
        assert!(
            gated.effective_device_fps >= 2.0 * plain.effective_device_fps,
            "gated {:.1} vs always-detect {:.1}",
            gated.effective_device_fps,
            plain.effective_device_fps
        );
        // ...at < 2% delivered-mAP cost, with no coverage loss.
        let cost = (plain.delivered_map - gated.delivered_map) / plain.delivered_map;
        assert!(
            cost < 0.02,
            "mAP cost {:.4} (gated {:.4} vs plain {:.4})",
            cost,
            gated.delivered_map,
            plain.delivered_map
        );
        assert!(gated.delivered_fps >= plain.delivered_fps - 1e-9);
        assert!(gated.skips > 0, "{gated:?}");
        assert!(gated.detect_fraction < 0.5, "{gated:?}");
    }

    #[test]
    fn highway_gate_stays_out_of_the_way() {
        let (_, outcomes) = content_sweep(7);
        let (plain, gated) = pair_for(&outcomes, "highway");
        // Sustained motion: nothing to skip, quality preserved.
        assert_eq!(gated.skips, 0, "{gated:?}");
        assert!(gated.detect_fraction >= 0.9, "{gated:?}");
        assert!(
            (gated.delivered_map - plain.delivered_map).abs() < 0.02,
            "gated {:.4} vs plain {:.4}",
            gated.delivered_map,
            plain.delivered_map
        );
    }

    #[test]
    fn sports_scene_cuts_force_fresh_detections() {
        let (_, outcomes) = content_sweep(7);
        let (_, gated) = pair_for(&outcomes, "sports");
        // The sports model cuts every 120 frames; each cut is a forced
        // refresh and the high base energy leaves nothing to skip.
        assert_eq!(gated.skips, 0, "{gated:?}");
        assert!(gated.refreshes >= 1, "{gated:?}");
    }

    #[test]
    fn gated_map_reduces_to_delivered_map_without_a_gate() {
        let p = &content_presets()[0];
        let ladder = eth_ladder();
        let out = preset_run(p, None, 7, false);
        let gated = gated_delivered_map(
            &out.report.streams,
            &ladder,
            (0.0, f64::INFINITY),
            &[],
            6.0,
        );
        let plain = delivered_map(&out.report.streams, &ladder, (0.0, f64::INFINITY));
        assert!((gated - plain).abs() < 1e-12, "{gated} vs {plain}");
    }

    #[test]
    fn traced_gated_run_carries_telemetry_for_known_presets_only() {
        let out = traced_gated_run("lobby", 7).expect("known preset");
        let tel = out.telemetry.as_ref().expect("traced run returns telemetry");
        assert_eq!(tel.traces.len() as u64, out.report.total_frames());
        assert!(tel.registry.counter_family_total("eva_frames_total") > 0);
        assert!(traced_gated_run("bogus", 7).is_none());
    }

    #[test]
    fn json_bundle_reparses_and_respects_scenario_selection() {
        let j = gate_json(5, "lobby").expect("known preset");
        let text = j.to_string();
        let back = Json::parse(&text).expect("gate JSON must reparse");
        assert_eq!(back.get("seed").and_then(Json::as_i64), Some(5));
        assert_eq!(back.get("lobby").unwrap().as_arr().unwrap().len(), 2);
        assert!(back.get("highway").is_none());
        assert!(back.get("sports").is_none());
        // Unknown presets are an error, not an empty success.
        assert!(gate_json(5, "bogus").is_none());
    }
}
