//! Figures 2 & 3: what random frame dropping does to four consecutive
//! frames (64–67) of ETH-Sunnyday, plus the §II-B headline numbers
//! (σ = 2.5 FPS zero-drop vs 14 FPS feed with mAP 86.9 % → 66.1 %).
//!
//! The driver reruns the exact scenario: single NCS2 + YOLOv3, (a)
//! zero-drop offline, (b) online at λ = 14 with dropping; it reports
//! per-frame detection staleness/IoU for frames 64–67 and the clip-level
//! mAP for both modes. `eva visualize` additionally dumps PPM images with
//! ground-truth and detection overlays.

use crate::coordinator::{run_offline, run_online, RunConfig, SchedulerKind, SourceMode};
use crate::detector::quality::{QualityModelDetector, QualityProfile};
use crate::device::link::LinkProfile;
use crate::device::{DetectorModelId, Fleet};
use crate::experiments::common::{map_against, quality_detectors};
use crate::types::Detection;
use crate::util::table::{f, pct, Table};
use crate::video::{generate, presets};

/// Result of the Figure 2/3 comparison.
#[derive(Debug, Clone)]
pub struct DroppingStudy {
    pub map_zero_drop: f64,
    pub map_online_single: f64,
    pub online_drop_rate: f64,
    /// (frame, stale_from, mean IoU of detections vs GT) for frames 64–67
    /// of the online run.
    pub focus_frames: Vec<(u64, Option<u64>, f64)>,
}

/// Mean best-IoU of detections against the frame's ground truth (a
/// per-frame alignment score — Figure 3's misalignment, quantified).
fn mean_alignment(dets: &[Detection], gts: &[crate::types::GtBox]) -> f64 {
    if gts.is_empty() {
        return 1.0;
    }
    let mut total = 0.0;
    for gt in gts {
        let best = dets
            .iter()
            .map(|d| d.bbox.iou(&gt.bbox))
            .fold(0.0f32, f32::max);
        total += best as f64;
    }
    total / gts.len() as f64
}

pub fn study(seed: u64) -> DroppingStudy {
    let spec = presets::eth_sunnyday(seed);
    let clip = generate(&spec, None);
    let model = DetectorModelId::Yolov3;

    // (a) zero-drop offline reference (Figure 2).
    let mut det = QualityModelDetector::new(
        QualityProfile::calibrated(model, &spec.name),
        seed ^ 0xF2,
    );
    let offline = run_offline(&clip, &mut det);
    let map_zero_drop = map_against(&clip, &offline);

    // (b) online, single stick, λ = 14 (Figure 3).
    let fleet = Fleet::ncs2_sticks(1, model, LinkProfile::usb3());
    let cfg = RunConfig::new(SchedulerKind::Fcfs, SourceMode::Paced, seed ^ 0xF3);
    let run = run_online(
        &clip,
        &fleet,
        quality_detectors(&fleet, &spec.name, seed ^ 0xF4),
        &cfg,
    );
    let dets: Vec<Vec<Detection>> = run.records.iter().map(|r| r.detections.clone()).collect();
    let map_online_single = map_against(&clip, &dets);

    let focus_frames = (64u64..=67)
        .map(|fid| {
            let r = &run.records[fid as usize];
            let align = mean_alignment(&r.detections, &clip.frames[fid as usize].ground_truth);
            (fid, r.stale_from, align)
        })
        .collect();

    DroppingStudy {
        map_zero_drop,
        map_online_single,
        online_drop_rate: run.metrics.drop_rate(),
        focus_frames,
    }
}

/// Render the study as the Figure 2/3 companion table.
pub fn fig2_3(seed: u64) -> (Table, DroppingStudy) {
    let s = study(seed);
    let mut t = Table::new(
        "Figures 2/3: zero-drop vs online dropping (ETH-Sunnyday, 1×NCS2, YOLOv3)",
        &["Quantity", "Zero-drop (Fig 2)", "Online λ=14 (Fig 3)"],
    );
    t.row(vec![
        "mAP (%)".into(),
        pct(s.map_zero_drop),
        pct(s.map_online_single),
    ]);
    t.row(vec![
        "Drop rate (%)".into(),
        "0.0".into(),
        f(s.online_drop_rate * 100.0, 1),
    ]);
    for (fid, stale, align) in &s.focus_frames {
        t.row(vec![
            format!("frame {fid} alignment (mean IoU)"),
            "fresh".into(),
            match stale {
                Some(src) => format!("{:.2} (stale from {src})", align),
                None => format!("{:.2} (fresh)", align),
            },
        ]);
    }
    (t, s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dropping_degrades_map_like_paper() {
        let s = study(11);
        // Paper: 86.9 -> 66.1. Shape: a large drop (≥ 10 points).
        assert!(
            s.map_zero_drop - s.map_online_single > 0.10,
            "zero-drop {} vs online {}",
            s.map_zero_drop,
            s.map_online_single
        );
        // ~(14-2.5)/14 ≈ 82% of frames dropped.
        assert!((s.online_drop_rate - 0.82).abs() < 0.06, "{}", s.online_drop_rate);
    }

    #[test]
    fn focus_frames_mostly_stale() {
        let s = study(12);
        let stale = s.focus_frames.iter().filter(|(_, st, _)| st.is_some()).count();
        assert!(stale >= 3, "frames 64-67: {stale} stale of 4");
    }
}
