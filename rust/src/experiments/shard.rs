//! Shard sweeps: sharded serving vs the single pool, skewed load under
//! different placement policies, and shard-loss recovery — all in
//! virtual time (see EXPERIMENTS.md §Shard for the measured numbers).
//!
//! * [`balanced_split`] — the parity sweep: the same offered load and
//!   total capacity served by 1, 2 and 4 shards. Work-conserving
//!   dispatch inside every shard means the split costs almost nothing:
//!   delivered FPS matches the single pool within a few percent.
//! * [`skewed_load`] — skewed arrival rates under least-loaded,
//!   round-robin and hash placement: least-loaded balances at admission
//!   time; load-blind policies start out of band and rely on the gossip
//!   rebalancer's migrations to restore it.
//! * [`shard_failure`] — a shard dies mid-run: its streams are orphaned
//!   for exactly one gossip interval (missed heartbeat), then re-placed
//!   on the survivors.

use crate::autoscale::policy::AutoscaleConfig;
use crate::control::EventLog;
use crate::device::DeviceInstance;
use crate::experiments::fleet::pool_of;
use crate::fleet::admission::AdmissionPolicy;
use crate::fleet::stream::StreamSpec;
use crate::shard::placement::PlacementPolicy;
use crate::shard::sim::{run_sharded, ShardReport, ShardScenario};
use crate::transport::frame::Codec;
use crate::util::json::Json;
use crate::util::table::{f, Table};
use std::collections::BTreeMap;

/// One row of the parity sweep.
#[derive(Debug, Clone)]
pub struct SplitOutcome {
    pub label: String,
    pub shards: usize,
    /// Total raw pool rate Σμ across shards (FPS).
    pub total_rate: f64,
    pub delivered_fps: f64,
    pub drop_rate: f64,
    pub migrations: usize,
}

/// Split `total_devices` uniform 2.5-FPS devices over `shards` equal
/// pools.
fn equal_pools(shards: usize, total_devices: usize, rate: f64) -> Vec<Vec<DeviceInstance>> {
    assert!(total_devices % shards == 0, "uneven split");
    let per = total_devices / shards;
    (0..shards).map(|_| pool_of(per, rate)).collect()
}

/// Parity sweep: 8 × 10-FPS streams (saturating), 8 × 2.5-FPS devices
/// total, served by 1 / 2 / 4 shards at equal total capacity.
pub fn balanced_split(seed: u64) -> (Table, Vec<SplitOutcome>) {
    let mut t = Table::new(
        "Sharded vs single pool at equal capacity (8 × 10-FPS streams, Σμ = 20)",
        &["config", "shards", "Σμ", "delivered σ", "vs single", "drop %", "migrations"],
    );
    let mut outcomes = Vec::new();
    let mut single_fps = 0.0f64;
    for &shards in &[1usize, 2, 4] {
        // Shallow windows relative to the gossip epoch: the epoch
        // quantisation drains window backlog across the boundary, so
        // window/Σμ must stay small against the interval for honest
        // throughput accounting (identical in every config here).
        let streams: Vec<StreamSpec> = (0..8)
            .map(|i| StreamSpec::new(&format!("cam{i}"), 10.0, 300).with_window(4))
            .collect();
        let scenario = ShardScenario::builder(equal_pools(shards, 8, 2.5), streams)
            .admission(AdmissionPolicy::admit_all())
            .gossip(10.0)
            .epochs(5)
            .seed(seed ^ shards as u64)
            .build();
        let report = run_sharded(&scenario);
        let outcome = SplitOutcome {
            label: format!("{shards} shard(s) × {} devices", 8 / shards),
            shards,
            total_rate: 20.0,
            delivered_fps: report.delivered_fps(),
            drop_rate: report.drop_rate(),
            migrations: report.migrations,
        };
        if shards == 1 {
            single_fps = outcome.delivered_fps;
        }
        t.row(vec![
            outcome.label.clone(),
            format!("{shards}"),
            f(outcome.total_rate, 1),
            f(outcome.delivered_fps, 2),
            f(outcome.delivered_fps / single_fps.max(1e-9), 3),
            f(outcome.drop_rate * 100.0, 1),
            format!("{}", outcome.migrations),
        ]);
        outcomes.push(outcome);
    }
    (t, outcomes)
}

/// One placement policy's outcome under skewed load.
#[derive(Debug, Clone)]
pub struct SkewOutcome {
    pub policy: &'static str,
    /// Max − min committed Σλ right after initial placement (FPS).
    pub initial_imbalance: f64,
    pub migrations: usize,
    pub delivered_fps: f64,
    pub drop_rate: f64,
}

fn skew_scenario(policy: PlacementPolicy, seed: u64) -> ShardScenario {
    // Skewed arrivals: three 6-FPS cams and three 2-FPS cams (Σλ = 24),
    // duration-matched at 40 s, over 2 shards × 6 devices (capacity
    // 14.25 each). Round-robin parks all three heavy cams on shard 0
    // (committed 18, 6 over the band); least-loaded lands 14 / 10.
    let mut streams = Vec::new();
    for i in 0..3 {
        streams.push(StreamSpec::new(&format!("heavy{i}"), 6.0, 240).with_window(4));
        streams.push(StreamSpec::new(&format!("light{i}"), 2.0, 80).with_window(4));
    }
    // Interleave as arrival order heavy, light, heavy, light, ...
    ShardScenario::builder(vec![pool_of(6, 2.5), pool_of(6, 2.5)], streams)
        .policy(policy)
        .gossip(5.0)
        .epochs(10)
        .seed(seed)
        .build()
}

/// Skewed-load sweep: placement policy vs initial imbalance and the
/// migrations the gossip rebalancer needs to restore the band.
pub fn skewed_load(seed: u64) -> (Table, Vec<SkewOutcome>) {
    let mut t = Table::new(
        "Skewed arrivals (3 × 6 FPS + 3 × 2 FPS over 2 shards): placement policy matters",
        &["policy", "initial imbalance", "migrations", "delivered σ", "drop %"],
    );
    let mut outcomes = Vec::new();
    for (policy, name) in [
        (PlacementPolicy::LeastLoaded, "least-loaded"),
        (PlacementPolicy::RoundRobin, "round-robin"),
        (PlacementPolicy::Hash, "hash"),
    ] {
        let report = run_sharded(&skew_scenario(policy, seed));
        let outcome = SkewOutcome {
            policy: name,
            initial_imbalance: report.initial_imbalance(),
            migrations: report.migrations,
            delivered_fps: report.delivered_fps(),
            drop_rate: report.drop_rate(),
        };
        t.row(vec![
            outcome.policy.to_string(),
            f(outcome.initial_imbalance, 1),
            format!("{}", outcome.migrations),
            f(outcome.delivered_fps, 2),
            f(outcome.drop_rate * 100.0, 1),
        ]);
        outcomes.push(outcome);
    }
    (t, outcomes)
}

/// Shard-loss outcome.
#[derive(Debug, Clone)]
pub struct FailoverOutcome {
    /// Streams orphaned by the loss.
    pub orphans: usize,
    /// Every orphan re-placed within one gossip interval.
    pub replaced_within_interval: bool,
    /// Worst loss→re-placement gap (seconds).
    pub worst_gap: f64,
    pub delivered_fps: f64,
    pub drop_rate: f64,
    /// Shards alive at the end.
    pub shards_alive: usize,
}

/// Shard failure mid-run: 9 × 2.5-FPS streams on 3 shards; shard 0 dies
/// at t = 20 s (epoch 2 of a 10-s gossip). Its three streams are
/// re-placed on the survivors at the next gossip round.
pub fn shard_failure(seed: u64) -> (Table, FailoverOutcome) {
    let streams: Vec<StreamSpec> = (0..9)
        .map(|i| StreamSpec::new(&format!("cam{i}"), 2.5, 200).with_window(4))
        .collect();
    let scenario = ShardScenario::builder(
        vec![pool_of(4, 2.5), pool_of(4, 2.5), pool_of(4, 2.5)],
        streams,
    )
    .gossip(10.0)
    .epochs(10)
    .seed(seed)
    .failure(2, 0)
    .build();
    let report = run_sharded(&scenario);
    let outcome = FailoverOutcome {
        orphans: report.orphan_count(),
        replaced_within_interval: report.orphans_replaced_within(report.gossip_interval),
        worst_gap: report.worst_orphan_gap(),
        delivered_fps: report.delivered_fps(),
        drop_rate: report.drop_rate(),
        shards_alive: report.shard_alive.iter().filter(|&&a| a).count(),
    };
    let mut t = Table::new(
        "Shard loss (1 of 3 dies at t=20): orphan re-placement within one gossip interval",
        &["orphans", "re-placed ≤ 1 interval", "worst gap (s)", "delivered σ", "drop %", "shards alive"],
    );
    t.row(vec![
        format!("{}", outcome.orphans),
        if outcome.replaced_within_interval { "yes" } else { "no" }.to_string(),
        f(outcome.worst_gap, 1),
        f(outcome.delivered_fps, 2),
        f(outcome.drop_rate * 100.0, 1),
        format!("{}", outcome.shards_alive),
    ]);
    (t, outcome)
}

/// The local-scaling parameters of the overload sweep: template 2.5-FPS
/// replicas up to 12 devices per shard (so the projected headroom
/// covers the 2× committed load), default hysteresis/cooldown.
pub fn overload_autoscale_cfg() -> AutoscaleConfig {
    AutoscaleConfig {
        p99_bound: 3.0,
        max_devices: 12,
        ..AutoscaleConfig::default()
    }
}

/// The shared ≈2× overload scenario behind [`autoscale_overload`] and
/// the transport parity pin
/// ([`crate::experiments::transport::autoscale_parity`]): round-robin
/// parks four 4.75-FPS cams — 19 FPS, twice the 9.5-FPS admission
/// capacity — on shard 0 while shard 1 idles at 2 FPS. With
/// `autoscale`, both shards embed local capacity control
/// (`overload_autoscale_cfg`); without it, the gossip rebalancer's
/// migrations are the only relief.
pub fn overload_scenario(seed: u64, autoscale: bool) -> ShardScenario {
    let mut streams = Vec::new();
    for i in 0..4 {
        // Interleaved heavy/light arrival order: RR lands every heavy
        // cam on shard 0, every light one on shard 1 (duration-matched
        // at 60 s).
        streams.push(StreamSpec::new(&format!("heavy{i}"), 4.75, 285).with_window(4));
        streams.push(StreamSpec::new(&format!("light{i}"), 0.5, 30).with_window(4));
    }
    let builder = ShardScenario::builder(vec![pool_of(4, 2.5), pool_of(4, 2.5)], streams)
        .policy(PlacementPolicy::RoundRobin)
        .gossip(10.0)
        .epochs(8)
        .seed(seed);
    if autoscale {
        builder.autoscale(overload_autoscale_cfg()).build()
    } else {
        builder.build()
    }
}

/// One mode's outcome on the overload scenario.
#[derive(Debug, Clone)]
pub struct OverloadOutcome {
    /// "migrate-only" or "autoscale".
    pub mode: &'static str,
    pub migrations: usize,
    /// Shard-local scale actions routed to the coordinator's audit log.
    pub scale_actions: usize,
    /// Worst per-stream p99 output latency over the run (seconds).
    pub worst_p99: f64,
    pub delivered_fps: f64,
    pub drop_rate: f64,
    /// The coordinator's audit log survives an encode→decode hop and
    /// carries every routed event.
    pub audit_clean: bool,
}

fn overload_outcome(mode: &'static str, report: &ShardReport) -> OverloadOutcome {
    let audit = report.audit_log();
    let audit_clean = EventLog::decode(&audit.encode())
        .map(|decoded| decoded == audit && decoded.len() == report.control_log.len())
        .unwrap_or(false);
    OverloadOutcome {
        mode,
        migrations: report.migrations,
        scale_actions: report.scale_actions(),
        worst_p99: report.worst_p99(),
        delivered_fps: report.delivered_fps(),
        drop_rate: report.drop_rate(),
        audit_clean,
    }
}

/// Overload sweep: local scaling vs migrate-only at 2× load. Shard 0 is
/// committed to twice its admission capacity; the migrate-only baseline
/// shifts what fits to shard 1 and degrades the rest, while per-shard
/// autoscale grows the pool in place — the digest's post-scale headroom
/// keeps the migration planner idle, so the migration count strictly
/// drops.
pub fn autoscale_overload(seed: u64) -> (Table, OverloadOutcome, OverloadOutcome) {
    let migrate_only = overload_outcome("migrate-only", &run_sharded(&overload_scenario(seed, false)));
    let autoscale = overload_outcome("autoscale", &run_sharded(&overload_scenario(seed, true)));
    let mut t = Table::new(
        "2× overload on shard 0: local scaling vs migrate-only",
        &["mode", "migrations", "scale actions", "worst p99 (s)", "delivered σ", "drop %", "audit clean"],
    );
    for o in [&migrate_only, &autoscale] {
        t.row(vec![
            o.mode.to_string(),
            format!("{}", o.migrations),
            format!("{}", o.scale_actions),
            f(o.worst_p99, 2),
            f(o.delivered_fps, 2),
            f(o.drop_rate * 100.0, 1),
            if o.audit_clean { "yes" } else { "no" }.to_string(),
        ]);
    }
    (t, migrate_only, autoscale)
}

fn overload_outcome_json(o: &OverloadOutcome) -> Json {
    let mut m = BTreeMap::new();
    m.insert("mode".into(), Json::Str(o.mode.to_string()));
    m.insert("migrations".into(), Json::Num(o.migrations as f64));
    m.insert("scale_actions".into(), Json::Num(o.scale_actions as f64));
    m.insert("worst_p99".into(), Json::Num(o.worst_p99));
    m.insert("delivered_fps".into(), Json::Num(o.delivered_fps));
    m.insert("drop_rate".into(), Json::Num(o.drop_rate));
    m.insert("audit_clean".into(), Json::Bool(o.audit_clean));
    Json::Obj(m)
}

/// Machine-readable autoscale bundle (the `eva shard --autoscale
/// --json` surface): the overload sweep plus the cross-transport parity
/// rows from [`crate::experiments::transport::autoscale_parity`].
pub fn autoscale_json(seed: u64) -> Json {
    let mut root = BTreeMap::new();
    root.insert("seed".into(), Json::Num(seed as f64));
    let (_, migrate_only, autoscale) = autoscale_overload(seed);
    root.insert(
        "autoscale_overload".into(),
        Json::Arr(vec![
            overload_outcome_json(&migrate_only),
            overload_outcome_json(&autoscale),
        ]),
    );
    let (_, parity) = crate::experiments::transport::autoscale_parity(seed);
    root.insert(
        "autoscale_parity".into(),
        Json::Arr(parity.iter().map(crate::experiments::transport::autoscale_parity_json).collect()),
    );
    Json::Obj(root)
}

/// Build the one-off CLI scenario shared by [`custom_run`] and
/// [`custom_run_remote`]: enough epochs to play the longest stream out,
/// plus one slack round.
#[allow(clippy::too_many_arguments)]
fn custom_scenario(
    shards: Vec<Vec<DeviceInstance>>,
    streams: Vec<StreamSpec>,
    policy: PlacementPolicy,
    admission: AdmissionPolicy,
    gossip: f64,
    seed: u64,
    autoscale: Option<AutoscaleConfig>,
    telemetry: bool,
    codec: Codec,
    groups: Option<usize>,
    token: Option<String>,
    forecast: Option<crate::forecast::ForecastConfig>,
) -> ShardScenario {
    let longest = streams.iter().map(|s| s.duration()).fold(0.0, f64::max);
    let epochs = ((longest / gossip.max(1e-3)).ceil() as usize).max(1) + 1;
    let mut builder = ShardScenario::builder(shards, streams)
        .policy(policy)
        .admission(admission)
        .gossip(gossip)
        .epochs(epochs)
        .seed(seed)
        .codec(codec);
    if let Some(size) = groups {
        builder = builder.groups(size);
    }
    if let Some(cfg) = autoscale {
        builder = builder.autoscale(cfg);
    }
    if telemetry {
        builder = builder.telemetry();
    }
    if let Some(t) = &token {
        builder = builder.token(t);
    }
    if let Some(cfg) = forecast {
        builder = builder.forecast(cfg);
    }
    builder.build()
}

/// A one-off sharded run from CLI parameters (the `eva shard
/// --scenario run [--autoscale]` path). `telemetry` arms the
/// per-slice metric snapshot in [`ShardReport::telemetry`] (the
/// `--metrics-out` surface); `codec` picks the control-plane payload
/// encoding and `groups` switches the rebalancer to grouped planning.
#[allow(clippy::too_many_arguments)]
pub fn custom_run(
    shards: Vec<Vec<DeviceInstance>>,
    streams: Vec<StreamSpec>,
    policy: PlacementPolicy,
    admission: AdmissionPolicy,
    gossip: f64,
    seed: u64,
    autoscale: Option<AutoscaleConfig>,
    telemetry: bool,
    codec: Codec,
    groups: Option<usize>,
    forecast: Option<crate::forecast::ForecastConfig>,
) -> ShardReport {
    run_sharded(&custom_scenario(
        shards, streams, policy, admission, gossip, seed, autoscale, telemetry, codec, groups,
        None, forecast,
    ))
}

/// [`custom_run`] with every shard behind a real loopback socket (the
/// `eva shard --scenario run --transport tcp|uds` path): same epoch
/// budget, but the co-simulation crosses [`crate::transport`] frames —
/// including the session capabilities and auth `token` (in the
/// handshake) and every shard-local scale action (as control frames).
#[allow(clippy::too_many_arguments)]
pub fn custom_run_remote(
    shards: Vec<Vec<DeviceInstance>>,
    streams: Vec<StreamSpec>,
    policy: PlacementPolicy,
    admission: AdmissionPolicy,
    gossip: f64,
    seed: u64,
    autoscale: Option<AutoscaleConfig>,
    telemetry: bool,
    codec: Codec,
    groups: Option<usize>,
    token: Option<String>,
    forecast: Option<crate::forecast::ForecastConfig>,
    transport: crate::shard::remote::RemoteTransport,
) -> anyhow::Result<ShardReport> {
    crate::shard::remote::run_sharded_remote(
        &custom_scenario(
            shards, streams, policy, admission, gossip, seed, autoscale, telemetry, codec, groups,
            token, forecast,
        ),
        transport,
    )
}

/// Machine-readable sweep results (the `--json` surface of `eva shard`);
/// `None` for an unknown scenario name.
pub fn shard_json(seed: u64, scenario: &str) -> Option<Json> {
    if !matches!(scenario, "split" | "skew" | "failure" | "all") {
        return None;
    }
    let mut root = BTreeMap::new();
    root.insert("seed".into(), Json::Num(seed as f64));
    if matches!(scenario, "split" | "all") {
        let (_, split) = balanced_split(seed);
        let rows: Vec<Json> = split
            .iter()
            .map(|o| {
                let mut m = BTreeMap::new();
                m.insert("label".into(), Json::Str(o.label.clone()));
                m.insert("shards".into(), Json::Num(o.shards as f64));
                m.insert("total_rate".into(), Json::Num(o.total_rate));
                m.insert("delivered_fps".into(), Json::Num(o.delivered_fps));
                m.insert("drop_rate".into(), Json::Num(o.drop_rate));
                m.insert("migrations".into(), Json::Num(o.migrations as f64));
                Json::Obj(m)
            })
            .collect();
        root.insert("balanced_split".into(), Json::Arr(rows));
    }
    if matches!(scenario, "skew" | "all") {
        let (_, skew) = skewed_load(seed);
        let rows: Vec<Json> = skew
            .iter()
            .map(|o| {
                let mut m = BTreeMap::new();
                m.insert("policy".into(), Json::Str(o.policy.to_string()));
                m.insert(
                    "initial_imbalance".into(),
                    Json::Num(o.initial_imbalance),
                );
                m.insert("migrations".into(), Json::Num(o.migrations as f64));
                m.insert("delivered_fps".into(), Json::Num(o.delivered_fps));
                m.insert("drop_rate".into(), Json::Num(o.drop_rate));
                Json::Obj(m)
            })
            .collect();
        root.insert("skewed_load".into(), Json::Arr(rows));
    }
    if matches!(scenario, "failure" | "all") {
        let (_, o) = shard_failure(seed);
        let mut m = BTreeMap::new();
        m.insert("orphans".into(), Json::Num(o.orphans as f64));
        m.insert(
            "replaced_within_interval".into(),
            Json::Bool(o.replaced_within_interval),
        );
        m.insert("worst_gap".into(), Json::Num(o.worst_gap));
        m.insert("delivered_fps".into(), Json::Num(o.delivered_fps));
        m.insert("drop_rate".into(), Json::Num(o.drop_rate));
        m.insert("shards_alive".into(), Json::Num(o.shards_alive as f64));
        root.insert("shard_failure".into(), Json::Obj(m));
    }
    Some(Json::Obj(root))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_split_matches_single_pool_within_5_percent() {
        // The acceptance criterion: a 2-shard balanced split delivers
        // within 5% of the single pool at equal capacity.
        let (_, outcomes) = balanced_split(17);
        let single = &outcomes[0];
        let two = &outcomes[1];
        assert_eq!(single.shards, 1);
        assert_eq!(two.shards, 2);
        let ratio = two.delivered_fps / single.delivered_fps;
        assert!(
            (ratio - 1.0).abs() < 0.05,
            "2-shard σ {:.2} vs single {:.2} (ratio {ratio:.3})",
            two.delivered_fps,
            single.delivered_fps
        );
        // And the pool is actually saturated: σ near Σμ.
        assert!(
            single.delivered_fps > 0.85 * single.total_rate,
            "σ {:.2} vs Σμ {:.2}",
            single.delivered_fps,
            single.total_rate
        );
    }

    #[test]
    fn skewed_load_least_loaded_balances_without_migrations() {
        let (_, outcomes) = skewed_load(19);
        let ll = &outcomes[0];
        let rr = &outcomes[1];
        assert_eq!(ll.policy, "least-loaded");
        assert_eq!(rr.policy, "round-robin");
        // Least-loaded lands 14/10 (imbalance 4) with no migrations;
        // round-robin lands 18/6 (imbalance 12) and needs the gossip
        // rebalancer.
        assert!((ll.initial_imbalance - 4.0).abs() < 1e-9, "{ll:?}");
        assert_eq!(ll.migrations, 0, "{ll:?}");
        assert!((rr.initial_imbalance - 12.0).abs() < 1e-9, "{rr:?}");
        assert!(rr.migrations >= 1, "{rr:?}");
        // The blind policy pays for its first out-of-band interval.
        assert!(
            rr.drop_rate >= ll.drop_rate - 1e-9,
            "rr {:.3} vs ll {:.3}",
            rr.drop_rate,
            ll.drop_rate
        );
    }

    #[test]
    fn shard_failure_replaces_orphans_within_one_interval() {
        let (_, o) = shard_failure(23);
        assert_eq!(o.orphans, 3, "{o:?}");
        assert!(o.replaced_within_interval, "{o:?}");
        assert!(o.worst_gap <= 10.0 + 1e-9, "{o:?}");
        assert_eq!(o.shards_alive, 2);
    }

    #[test]
    fn local_scaling_strictly_cuts_migrations_at_2x_load() {
        // The acceptance criterion: per-shard scaling strictly reduces
        // the migration count vs migrate-only at 2× load, holds the
        // worst p99 inside the configured band, and every scale action
        // survives the coordinator's audit-log round trip.
        let (_, migrate_only, autoscale) = autoscale_overload(43);
        assert!(migrate_only.migrations >= 1, "{migrate_only:?}");
        assert_eq!(migrate_only.scale_actions, 0, "{migrate_only:?}");
        assert!(
            autoscale.migrations < migrate_only.migrations,
            "autoscale {} vs migrate-only {}",
            autoscale.migrations,
            migrate_only.migrations
        );
        assert!(autoscale.scale_actions >= 1, "{autoscale:?}");
        assert!(autoscale.audit_clean && migrate_only.audit_clean);
        let bound = overload_autoscale_cfg().p99_bound;
        assert!(
            autoscale.worst_p99 <= bound + 1e-9,
            "worst p99 {:.2} vs band {bound}",
            autoscale.worst_p99
        );
        // Scaling must not cost throughput relative to the baseline.
        assert!(
            autoscale.delivered_fps >= migrate_only.delivered_fps - 1e-9,
            "autoscale σ {:.2} vs migrate-only σ {:.2}",
            autoscale.delivered_fps,
            migrate_only.delivered_fps
        );
    }

    #[test]
    fn autoscale_json_bundle_reparses() {
        let j = autoscale_json(7);
        let back = Json::parse(&j.to_string()).expect("autoscale JSON must reparse");
        assert_eq!(back.get("seed").and_then(Json::as_i64), Some(7));
        let overload = back.get("autoscale_overload").unwrap().as_arr().unwrap();
        assert_eq!(overload.len(), 2);
        assert_eq!(
            overload[0].get("mode").and_then(Json::as_str),
            Some("migrate-only")
        );
        let parity = back.get("autoscale_parity").unwrap().as_arr().unwrap();
        assert_eq!(parity.len(), 3);
    }

    #[test]
    fn json_bundle_reparses_and_respects_scenario_selection() {
        let j = shard_json(5, "all").expect("known scenario");
        let back = Json::parse(&j.to_string()).expect("shard JSON must reparse");
        assert_eq!(back.get("seed").and_then(Json::as_i64), Some(5));
        assert_eq!(
            back.get("balanced_split").unwrap().as_arr().unwrap().len(),
            3
        );
        assert_eq!(back.get("skewed_load").unwrap().as_arr().unwrap().len(), 3);
        assert!(back.get("shard_failure").unwrap().as_obj().is_some());
        let split_only = shard_json(5, "split").expect("known scenario");
        assert!(split_only.get("balanced_split").is_some());
        assert!(split_only.get("skewed_load").is_none());
        assert!(shard_json(5, "bogus").is_none());
    }
}
