//! Table VI: power efficiency of the detection hardware.

use crate::device::energy::fps_per_watt;
use crate::device::{DetectorModelId, DeviceKind};
use crate::util::table::{f, Table};

/// Structured Table VI row.
#[derive(Debug, Clone, Copy)]
pub struct EnergyRow {
    pub kind: DeviceKind,
    pub tdp: f64,
    pub fps: f64,
    pub fps_per_watt: f64,
}

/// The paper's four execution environments running YOLOv3 (zero-drop μ).
pub fn rows() -> Vec<EnergyRow> {
    [
        DeviceKind::Ncs2,
        DeviceKind::SlowCpu,
        DeviceKind::FastCpu,
        DeviceKind::TitanX,
    ]
    .into_iter()
    .map(|kind| {
        let fps = kind.service_rate(DetectorModelId::Yolov3);
        EnergyRow {
            kind,
            tdp: kind.tdp_watts(),
            fps,
            fps_per_watt: fps_per_watt(fps, kind),
        }
    })
    .collect()
}

/// Table VI in the paper's layout.
pub fn table6() -> (Table, Vec<EnergyRow>) {
    let rs = rows();
    let mut t = Table::new(
        "Table VI: Power Efficiency of Different Hardware (YOLOv3, zero-drop)",
        &["Device", "TDP (W)", "Detection FPS", "FPS / Watt"],
    );
    for r in &rs {
        t.row(vec![
            r.kind.label().to_string(),
            f(r.tdp, 0),
            f(r.fps, 1),
            f(r.fps_per_watt, 2),
        ]);
    }
    (t, rs)
}

/// Extension: joules per processed frame for an n-stick fleet vs a GPU —
/// the energy argument §IV-B makes qualitatively, quantified.
pub fn joules_per_frame_comparison() -> (Table, Vec<(String, f64)>) {
    let mut t = Table::new(
        "Energy per processed frame (busy-power model)",
        &["Configuration", "J / frame"],
    );
    let mut out = Vec::new();
    // n sticks: each frame costs (1/2.5 s) × 2 W on one stick.
    for n in [1usize, 4, 7] {
        let j = (1.0 / 2.5) * DeviceKind::Ncs2.tdp_watts();
        let name = format!("{n}× NCS2 (YOLOv3)");
        t.row(vec![name.clone(), f(j, 2)]);
        out.push((name, j));
    }
    let gpu = (1.0 / 35.0) * DeviceKind::TitanX.tdp_watts();
    t.row(vec!["GTX TITAN X (YOLOv3)".to_string(), f(gpu, 2)]);
    out.push(("GTX TITAN X (YOLOv3)".to_string(), gpu));
    let fast = (1.0 / 13.5) * DeviceKind::FastCpu.tdp_watts();
    t.row(vec!["Fast CPU (YOLOv3)".to_string(), f(fast, 2)]);
    out.push(("Fast CPU (YOLOv3)".to_string(), fast));
    (t, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table6_matches_paper() {
        let rs = rows();
        let ncs2 = &rs[0];
        assert_eq!(ncs2.tdp, 2.0);
        assert!((ncs2.fps_per_watt - 1.25).abs() < 1e-9);
        // Ordering: NCS2 > GPU > fast CPU > slow CPU.
        assert!(rs[0].fps_per_watt > rs[3].fps_per_watt);
        assert!(rs[3].fps_per_watt > rs[2].fps_per_watt);
        assert!(rs[2].fps_per_watt > rs[1].fps_per_watt);
    }

    #[test]
    fn stick_cheaper_per_frame_than_gpu_and_cpu() {
        let (_, rows) = joules_per_frame_comparison();
        let stick = rows[0].1;
        let gpu = rows.iter().find(|(n, _)| n.contains("TITAN")).unwrap().1;
        let cpu = rows.iter().find(|(n, _)| n.contains("Fast CPU")).unwrap().1;
        assert!(stick < gpu && stick < cpu);
    }
}
