//! Coordinator scale sweep: per-epoch planning cost at 100k+ streams.
//!
//! The sharded co-simulation runs whole fleets through the serving
//! engine, which caps how many shards an experiment can afford. This
//! driver isolates the *coordinator's* per-epoch work — digest reads,
//! rebalance planning, control-plane payload bytes — over a synthetic
//! fleet large enough to expose asymptotics (the default sweep tops out
//! at 4096 shards × 25 streams = 102 400 streams):
//!
//! * **Flat vs grouped planning** ([`crate::shard::plan`]): with
//!   overload localised to a bounded set of hot shards, the flat
//!   planner reads all M views per epoch while the grouped planner
//!   reads ⌈M/k⌉ digests plus the members of the few descended groups —
//!   with k ≈ √M that is O(√M) reads, and the sweep's
//!   [`PlanStats::reads`] column shows the gap widening as M grows
//!   (the deterministic counters are what
//!   `benches/coordinator_scale.rs` pins; wall-clock is reported as
//!   corroboration).
//! * **Binary vs JSON digest frames** ([`crate::control::binary`]):
//!   the same per-shard digest, framed in both codecs, summed over the
//!   fleet — the compact codec must hold a ≥3× size advantage at scale.
//! * **Delta vs snapshot digest streams** ([`crate::shard::group`]):
//!   epochs where only churned shards ship vs full-fleet snapshots,
//!   under bounded churn.
//!
//! See EXPERIMENTS.md §Scale for the measured numbers.

use std::collections::BTreeMap;

use crate::shard::gossip::Headroom;
use crate::shard::group::{encode_delta, DeltaEncoder, DigestDelta};
use crate::shard::placement::ShardView;
use crate::shard::plan::{plan_flat, plan_grouped, PlanStats};
use crate::transport::frame::Codec;
use crate::transport::msg::TransportMsg;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::table::{f, Table};

/// Hot shards per fleet: overload stays localised (a fixed count, not a
/// fixed fraction), which is what makes sub-linear coordination
/// possible at all — and is how real incidents look: a few cameras
/// spike, the fleet does not. The hot set is contiguous (one rack, one
/// venue), so it lands in O(1) shard groups rather than salting every
/// group with one hot member.
pub const HOT_SHARDS: usize = 8;

/// One fleet size's measurements.
#[derive(Debug, Clone)]
pub struct ScalePoint {
    pub shards: usize,
    pub streams: usize,
    /// Planner group size k ≈ √M.
    pub group_size: usize,
    pub flat: PlanStats,
    pub grouped: PlanStats,
    /// Wall-clock seconds for one flat / grouped plan invocation.
    pub flat_secs: f64,
    pub grouped_secs: f64,
    /// One gossip round's digest frames, summed over the fleet.
    pub json_digest_bytes: usize,
    pub binary_digest_bytes: usize,
    /// Digest-stream bytes over the churn epochs: deltas vs full
    /// snapshots (both in the binary codec).
    pub delta_bytes: usize,
    pub snapshot_bytes: usize,
}

impl ScalePoint {
    /// JSON-over-binary digest size ratio (the ≥3× criterion).
    pub fn codec_ratio(&self) -> f64 {
        self.json_digest_bytes as f64 / (self.binary_digest_bytes as f64).max(1.0)
    }

    /// Snapshot-over-delta stream size ratio.
    pub fn delta_ratio(&self) -> f64 {
        self.snapshot_bytes as f64 / (self.delta_bytes as f64).max(1.0)
    }
}

/// A deterministic synthetic fleet: M shard views (a bounded hot set
/// over capacity, the rest comfortably in band) plus the resident list
/// the planner consumes. Demands carry per-stream jitter so the digest
/// floats are not round numbers — codec size comparisons stay honest.
fn synthetic_fleet(
    shards: usize,
    streams_per_shard: usize,
    seed: u64,
) -> (Vec<ShardView>, Vec<(usize, f64, usize)>) {
    let mut rng = Rng::new(seed ^ 0x5CA1_EB10);
    let capacity = 23.75; // 10 × 2.5-FPS replicas at 95% target util
    let hot_count = HOT_SHARDS.min(shards / 2);
    let mut views = Vec::with_capacity(shards);
    let mut residents = Vec::with_capacity(shards * streams_per_shard);
    for sh in 0..shards {
        let hot = sh < hot_count;
        // Hot shards commit ~130% of capacity, the rest ~70%.
        let load = if hot { 1.3 } else { 0.7 };
        let mut committed = 0.0;
        for i in 0..streams_per_shard {
            let demand = capacity * load / streams_per_shard as f64
                * rng.range(0.9, 1.1);
            committed += demand;
            residents.push((sh * streams_per_shard + i, demand, sh));
        }
        views.push(ShardView {
            shard: sh,
            alive: true,
            capacity,
            committed,
            forecast: None,
        });
    }
    (views, residents)
}

/// One gossip round's digest payload bytes, summed over the fleet.
/// Payload bytes, not framed bytes: the 8-byte frame header is codec-
/// independent overhead, and the codec claim is about the payloads
/// (`payload_cap_is_configurable_but_defaults_hold` covers framing).
fn digest_payload_bytes(views: &[ShardView], at: f64, codec: Codec) -> usize {
    views
        .iter()
        .map(|v| {
            let msg = TransportMsg::Digest {
                shard: v.shard,
                at,
                capacity: v.capacity,
                committed: v.committed,
                forecast: None,
            };
            match codec {
                Codec::Json => msg.encode().len(),
                Codec::Binary => crate::control::binary::encode_msg(&msg).len(),
            }
        })
        .sum()
}

/// Delta vs snapshot digest-stream bytes over `epochs` epochs with
/// `churn` shards changing materially per epoch (binary codec both
/// ways, same [`Headroom`] content).
fn delta_stream_bytes(
    views: &[ShardView],
    epochs: usize,
    churn: usize,
    seed: u64,
) -> (usize, usize) {
    let m = views.len();
    let mut rng = Rng::new(seed ^ 0xD1_6E57);
    let mut current: Vec<Option<Headroom>> = views
        .iter()
        .map(|v| {
            Some(Headroom {
                shard: v.shard,
                at: 0.0,
                capacity: v.capacity,
                committed: v.committed,
                forecast: None,
            })
        })
        .collect();
    // Resync far beyond the horizon: epoch 0 is the one full frame.
    let mut enc = DeltaEncoder::new(m, 0.05, epochs + 1);
    let (mut delta_bytes, mut snapshot_bytes) = (0, 0);
    for epoch in 0..epochs {
        let at = epoch as f64 * 5.0;
        for slot in current.iter_mut().flatten() {
            slot.at = at;
        }
        if epoch > 0 {
            for _ in 0..churn {
                let sh = rng.below(m as u64) as usize;
                if let Some(h) = current[sh].as_mut() {
                    h.committed += rng.range(0.5, 1.5);
                }
            }
        }
        let delta = enc.encode(epoch, at, &current);
        delta_bytes += encode_delta(&delta).len();
        let full = DigestDelta {
            epoch,
            at,
            full: true,
            entries: current.iter().flatten().copied().collect(),
            dead: Vec::new(),
        };
        snapshot_bytes += encode_delta(&full).len();
    }
    (delta_bytes, snapshot_bytes)
}

/// Integer √M, the default planner group size.
pub fn default_group_size(shards: usize) -> usize {
    ((shards as f64).sqrt().round() as usize).max(1)
}

/// Measure one fleet size.
pub fn scale_point(shards: usize, streams_per_shard: usize, seed: u64) -> ScalePoint {
    let (views, residents) = synthetic_fleet(shards, streams_per_shard, seed);
    let group_size = default_group_size(shards);

    let t = std::time::Instant::now();
    let (_, flat) = plan_flat(&views, &residents);
    let flat_secs = t.elapsed().as_secs_f64();

    let t = std::time::Instant::now();
    let (_, grouped) = plan_grouped(&views, &residents, group_size);
    let grouped_secs = t.elapsed().as_secs_f64();

    // Non-round timestamp: keeps the JSON number rendering honest.
    let at = 5.125;
    let json_digest_bytes = digest_payload_bytes(&views, at, Codec::Json);
    let binary_digest_bytes = digest_payload_bytes(&views, at, Codec::Binary);

    // Churn 1% of the fleet (at least one shard) per epoch.
    let churn = (shards / 100).max(1);
    let (delta_bytes, snapshot_bytes) = delta_stream_bytes(&views, 8, churn, seed);

    ScalePoint {
        shards,
        streams: shards * streams_per_shard,
        group_size,
        flat,
        grouped,
        flat_secs,
        grouped_secs,
        json_digest_bytes,
        binary_digest_bytes,
        delta_bytes,
        snapshot_bytes,
    }
}

/// The scale sweep over a shard-count ladder (default: 256 → 4096,
/// 25 streams per shard, topping out at 102 400 streams).
pub fn coordinator_scale_at(
    shard_counts: &[usize],
    streams_per_shard: usize,
    seed: u64,
) -> (Table, Vec<ScalePoint>) {
    let mut t = Table::new(
        "Coordinator per-epoch cost at scale (bounded hot set, k ≈ √M)",
        &[
            "shards", "streams", "k", "flat reads", "grouped reads", "descended",
            "codec ratio", "delta ratio", "flat (ms)", "grouped (ms)",
        ],
    );
    let mut points = Vec::new();
    for &m in shard_counts {
        let p = scale_point(m, streams_per_shard, seed);
        t.row(vec![
            format!("{}", p.shards),
            format!("{}", p.streams),
            format!("{}", p.group_size),
            format!("{}", p.flat.reads()),
            format!("{}", p.grouped.reads()),
            format!("{}", p.grouped.groups_descended),
            f(p.codec_ratio(), 2),
            f(p.delta_ratio(), 2),
            f(p.flat_secs * 1e3, 3),
            f(p.grouped_secs * 1e3, 3),
        ]);
        points.push(p);
    }
    (t, points)
}

/// Default ladder: 4× shard steps to 4096 shards (102 400 streams).
pub fn coordinator_scale(seed: u64) -> (Table, Vec<ScalePoint>) {
    coordinator_scale_at(&[256, 1024, 4096], 25, seed)
}

fn point_json(p: &ScalePoint) -> Json {
    let mut m = BTreeMap::new();
    m.insert("shards".into(), Json::Num(p.shards as f64));
    m.insert("streams".into(), Json::Num(p.streams as f64));
    m.insert("group_size".into(), Json::Num(p.group_size as f64));
    m.insert("flat_reads".into(), Json::Num(p.flat.reads() as f64));
    m.insert("grouped_reads".into(), Json::Num(p.grouped.reads() as f64));
    m.insert(
        "groups_descended".into(),
        Json::Num(p.grouped.groups_descended as f64),
    );
    m.insert(
        "flat_migrations".into(),
        Json::Num(p.flat.migrations as f64),
    );
    m.insert(
        "grouped_migrations".into(),
        Json::Num(p.grouped.migrations as f64),
    );
    m.insert("flat_secs".into(), Json::Num(p.flat_secs));
    m.insert("grouped_secs".into(), Json::Num(p.grouped_secs));
    m.insert(
        "json_digest_bytes".into(),
        Json::Num(p.json_digest_bytes as f64),
    );
    m.insert(
        "binary_digest_bytes".into(),
        Json::Num(p.binary_digest_bytes as f64),
    );
    m.insert("codec_ratio".into(), Json::Num(p.codec_ratio()));
    m.insert("delta_bytes".into(), Json::Num(p.delta_bytes as f64));
    m.insert("snapshot_bytes".into(), Json::Num(p.snapshot_bytes as f64));
    m.insert("delta_ratio".into(), Json::Num(p.delta_ratio()));
    Json::Obj(m)
}

/// Machine-readable sweep (the `eva shard --scenario scale --json`
/// surface; CI uploads it as `BENCH_coordinator_scale.json`).
pub fn scale_json(seed: u64) -> Json {
    let mut root = BTreeMap::new();
    root.insert("seed".into(), Json::Num(seed as f64));
    let (_, points) = coordinator_scale(seed);
    root.insert(
        "coordinator_scale".into(),
        Json::Arr(points.iter().map(point_json).collect()),
    );
    Json::Obj(root)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grouped_reads_grow_sublinearly_on_a_small_ladder() {
        // 4× the shards must cost the grouped planner well under 4× the
        // reads (k ≈ √M ⇒ ~2×), while the flat planner is exactly
        // linear. Small ladder here; the bench pins the 100k+ point.
        let (_, points) = coordinator_scale_at(&[64, 256], 4, 11);
        let (small, big) = (&points[0], &points[1]);
        assert_eq!(big.flat.reads(), 4 * small.flat.reads());
        let growth = big.grouped.reads() as f64 / small.grouped.reads() as f64;
        assert!(growth < 2.5, "grouped reads grew {growth:.2}× on a 4× fleet");
        assert!(big.grouped.reads() < big.flat.reads());
        // Hot-set overload is what the planner actually sees.
        assert!(big.grouped.groups_descended >= 1);
        assert!(big.flat.migrations >= 1);
    }

    #[test]
    fn binary_digests_beat_json_by_3x_and_deltas_beat_snapshots() {
        let p = scale_point(128, 4, 13);
        assert!(
            p.codec_ratio() >= 3.0,
            "binary {} vs json {} (ratio {:.2})",
            p.binary_digest_bytes,
            p.json_digest_bytes,
            p.codec_ratio()
        );
        // 1% churn over 8 epochs: the delta stream is a fraction of
        // shipping full snapshots every epoch.
        assert!(
            p.delta_ratio() >= 3.0,
            "delta {} vs snapshot {} (ratio {:.2})",
            p.delta_bytes,
            p.snapshot_bytes,
            p.delta_ratio()
        );
    }

    #[test]
    fn scale_json_reparses_with_one_row_per_point() {
        // Tiny ladder through the same JSON shape the CLI emits.
        let mut root = BTreeMap::new();
        root.insert("seed".into(), Json::Num(3.0));
        let (_, points) = coordinator_scale_at(&[32, 64], 3, 3);
        root.insert(
            "coordinator_scale".into(),
            Json::Arr(points.iter().map(point_json).collect()),
        );
        let j = Json::Obj(root);
        let back = Json::parse(&j.to_string()).expect("scale JSON must reparse");
        let rows = back.get("coordinator_scale").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("shards").and_then(Json::as_i64), Some(32));
        assert!(rows[1].get("codec_ratio").and_then(Json::as_f64).unwrap() > 1.0);
    }
}
