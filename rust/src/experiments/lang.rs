//! Table X: impact of the implementation language — the experiment that
//! motivates this Rust coordinator.
//!
//! The paper's Python prototype is bounded by the GIL: OpenVINO calls
//! release it, but frame pre/post-processing and scheduling serialise, so
//! throughput plateaus near 9.8 FPS regardless of stick count. The C++
//! (here: Rust) implementation pays a tiny per-frame synchronisation cost
//! but scales linearly. We model the GIL as a serial per-frame resource
//! (`gil_serial_time`) in the same DES.
//!
//! Note the Table X prototype ran faster per stick (4.5–4.8 FPS) than the
//! Table V configuration; we use its own calibrated rates.

use crate::coordinator::{run_online, RunConfig, SchedulerKind, SourceMode};
use crate::device::link::LinkProfile;
use crate::device::{DetectorModelId, DeviceInstance, DeviceKind, Fleet};
use crate::experiments::common::quality_detectors;
use crate::util::table::{f, Table};
use crate::video::{generate, presets};

/// GIL-held serial work per frame in the Python prototype (sets the
/// observed ~9.8 FPS plateau).
pub const GIL_SERIAL_TIME: f64 = 1.0 / 9.85;
/// Device-only (GIL-released OpenVINO call) rate backed out of the
/// prototype's 4.8 FPS single-stick figure:
/// 1/4.8 = GIL_SERIAL_TIME + 1/rate  ⇒  rate ≈ 9.36.
pub const STICK_RATE_PY: f64 = 9.36;
/// Lock-free-path synchronisation cost per frame in the compiled
/// implementation (explains C++ trailing Python slightly at n = 1..2).
pub const CPP_SYNC_TIME: f64 = 0.004;
/// Device-only rate for the compiled prototype: 1/4.5 − 0.004 ⇒ ≈ 4.58.
pub const STICK_RATE_CPP: f64 = 4.58;

fn fleet(n: usize, rate: f64) -> Fleet {
    Fleet {
        devices: (0..n)
            .map(|i| {
                let mut d =
                    DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, i, rate);
                d.jitter_cv = 0.02;
                d
            })
            .collect(),
        hub: Some(LinkProfile::usb3()),
    }
}

/// Measure throughput for `n` sticks under one language model.
pub fn throughput(n: usize, python: bool, seed: u64) -> f64 {
    let clip = generate(&presets::adl_rundle6(seed), None);
    let fl = fleet(n, if python { STICK_RATE_PY } else { STICK_RATE_CPP });
    let mut cfg = RunConfig::new(SchedulerKind::Fcfs, SourceMode::Saturated, seed);
    cfg.gil_serial_time = Some(if python { GIL_SERIAL_TIME } else { CPP_SYNC_TIME });
    let run = run_online(
        &clip,
        &fl,
        quality_detectors(&fl, "adl_rundle6", seed),
        &cfg,
    );
    run.metrics.processing_fps()
}

/// Table X: Python vs C++ scaling, n = 1..=7.
pub fn table10(seed: u64) -> (Table, Vec<(usize, f64, f64)>) {
    let mut header = vec!["#NCS".to_string()];
    for n in 1..=7 {
        header.push(format!("{n}"));
    }
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "Table X: Impact of Programming Languages on parallel detection FPS (YOLOv3, ADL-Rundle-6)",
        &hdr,
    );
    let mut py_row = vec!["Python".to_string()];
    let mut cpp_row = vec!["C++ (rust)".to_string()];
    let mut results = Vec::new();
    for n in 1..=7usize {
        let py = throughput(n, true, seed + n as u64);
        let cpp = throughput(n, false, seed + 50 + n as u64);
        py_row.push(f(py, 1));
        cpp_row.push(f(cpp, 1));
        results.push((n, py, cpp));
    }
    t.row(py_row);
    t.row(cpp_row);
    (t, results)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn python_plateaus_cpp_scales() {
        let py3 = throughput(3, true, 1);
        let py7 = throughput(7, true, 2);
        let cpp7 = throughput(7, false, 3);
        // Python stuck near 9.8 from n=3 on.
        assert!((py3 - 9.8).abs() < 0.7, "py n=3 {py3}");
        assert!((py7 - 9.8).abs() < 0.7, "py n=7 {py7}");
        // C++ keeps scaling (paper: 32.4 at n=7).
        assert!(cpp7 > 28.0, "cpp n=7 {cpp7}");
    }

    #[test]
    fn python_slightly_ahead_at_n1() {
        // Paper: 4.8 vs 4.5 at one stick (C++ sync overhead).
        let py1 = throughput(1, true, 4);
        let cpp1 = throughput(1, false, 5);
        assert!((py1 - 4.8).abs() < 0.4, "py1 {py1}");
        assert!((cpp1 - 4.5).abs() < 0.4, "cpp1 {cpp1}");
    }
}
