//! Shared experiment plumbing: run one (fleet, scheduler, video, model)
//! cell in both modes and evaluate mAP.

use crate::coordinator::{run_offline, run_online, RunConfig, SchedulerKind, SourceMode};
use crate::detector::quality::{QualityModelDetector, QualityProfile};
use crate::detector::Detector;
use crate::device::{DetectorModelId, Fleet};
use crate::eval::evaluate_map;
use crate::types::{Detection, GtBox, CLASSES};
use crate::video::Clip;

/// Measured numbers for one table cell.
#[derive(Debug, Clone, Copy)]
pub struct CellOutcome {
    /// Saturated processing capacity σ_P (the paper's "Detection FPS").
    pub fps: f64,
    /// mAP of the paced online run (dropped frames included).
    pub map: f64,
    /// Drop rate of the paced run.
    pub drop_rate: f64,
}

/// Per-replica quality-model detectors for a fleet on a given video.
pub fn quality_detectors(fleet: &Fleet, video: &str, seed: u64) -> Vec<Box<dyn Detector>> {
    fleet
        .devices
        .iter()
        .enumerate()
        .map(|(i, d)| {
            Box::new(QualityModelDetector::new(
                QualityProfile::calibrated(d.model, video),
                seed.wrapping_add(7919 * (i as u64 + 1)),
            )) as Box<dyn Detector>
        })
        .collect()
}

/// mAP of a set of per-frame detections against a clip's ground truth.
pub fn map_against(clip: &Clip, dets: &[Vec<Detection>]) -> f64 {
    let gt: Vec<&[GtBox]> = clip
        .frames
        .iter()
        .map(|f| f.ground_truth.as_slice())
        .collect();
    evaluate_map(dets, &gt, CLASSES.len(), 0.5).map
}

/// Zero-frame-dropping offline reference (Figure 1a): σ = μ and the
/// detector's intrinsic mAP.
pub fn zero_drop_baseline(clip: &Clip, model: DetectorModelId, seed: u64) -> (f64, f64) {
    let mut det = QualityModelDetector::new(
        QualityProfile::calibrated(model, &clip.spec.name),
        seed,
    );
    let dets = run_offline(clip, &mut det);
    let mu = crate::device::DeviceKind::Ncs2.service_rate(model);
    (mu, map_against(clip, &dets))
}

/// Saturated capacity σ_P of a fleet (Detection-FPS column).
pub fn saturated_fps(clip: &Clip, fleet: &Fleet, scheduler: SchedulerKind, seed: u64) -> f64 {
    let cfg = RunConfig::new(scheduler, SourceMode::Saturated, seed);
    let run = run_online(
        clip,
        fleet,
        quality_detectors(fleet, &clip.spec.name, seed),
        &cfg,
    );
    run.metrics.processing_fps()
}

/// Online paced run: mAP over all frames (stale fills included) + drop rate.
pub fn online_map(clip: &Clip, fleet: &Fleet, scheduler: SchedulerKind, seed: u64) -> (f64, f64) {
    let cfg = RunConfig::new(scheduler, SourceMode::Paced, seed);
    let run = run_online(
        clip,
        fleet,
        quality_detectors(fleet, &clip.spec.name, seed),
        &cfg,
    );
    let dets: Vec<Vec<Detection>> = run.records.iter().map(|r| r.detections.clone()).collect();
    (map_against(clip, &dets), run.metrics.drop_rate())
}

/// Full cell: capacity + online quality.
pub fn run_cell(clip: &Clip, fleet: &Fleet, scheduler: SchedulerKind, seed: u64) -> CellOutcome {
    let fps = saturated_fps(clip, fleet, scheduler, seed);
    let (map, drop_rate) = online_map(clip, fleet, scheduler, seed);
    CellOutcome {
        fps,
        map,
        drop_rate,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::link::LinkProfile;
    use crate::video::{generate, presets};

    #[test]
    fn cell_outcome_sane() {
        let clip = generate(&presets::eth_sunnyday(1), None);
        let fleet = Fleet::ncs2_sticks(4, DetectorModelId::Yolov3, LinkProfile::usb3());
        let cell = run_cell(&clip, &fleet, SchedulerKind::Fcfs, 3);
        assert!(cell.fps > 8.0 && cell.fps < 12.0, "fps {}", cell.fps);
        assert!(cell.map > 0.5 && cell.map <= 1.0, "map {}", cell.map);
        assert!(cell.drop_rate > 0.0 && cell.drop_rate < 0.6);
    }

    #[test]
    fn zero_drop_matches_calibration() {
        let clip = generate(&presets::eth_sunnyday(2), None);
        let (mu, map) = zero_drop_baseline(&clip, DetectorModelId::Yolov3, 5);
        assert_eq!(mu, 2.5);
        assert!((map - 0.869).abs() < 0.08, "map {map}");
    }
}
