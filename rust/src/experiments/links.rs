//! Table IX (USB 2.0 vs 3.0 impact) and an extension sweep over the
//! Table VIII link registry for multi-edge-node deployment planning.

use crate::coordinator::SchedulerKind;
use crate::device::link::LinkProfile;
use crate::device::{DetectorModelId, Fleet};
use crate::experiments::common::saturated_fps;
use crate::util::table::{f, Table};
use crate::video::{generate, presets};

/// Structured Table IX results: per model × link, σ_P for n = 1..=max_n.
#[derive(Debug, Clone)]
pub struct UsbSweep {
    pub model: DetectorModelId,
    pub link: LinkProfile,
    pub by_n: Vec<(usize, f64)>,
}

pub fn sweep(model: DetectorModelId, link: LinkProfile, max_n: usize, seed: u64) -> UsbSweep {
    let clip = generate(&presets::adl_rundle6(seed), None);
    let mut by_n = Vec::with_capacity(max_n);
    for n in 1..=max_n {
        let fleet = Fleet::ncs2_sticks(n, model, link.clone());
        let fps = saturated_fps(&clip, &fleet, SchedulerKind::Fcfs, seed + n as u64);
        by_n.push((n, fps));
    }
    UsbSweep { model, link, by_n }
}

/// Table IX: USB 2.0 vs USB 3.0 for both models on ADL-Rundle-6.
pub fn table9(seed: u64) -> (Table, Vec<UsbSweep>) {
    let mut header = vec!["Model".to_string(), "Port".to_string()];
    for n in 1..=7 {
        header.push(format!("{n}"));
    }
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "Table IX: Impact of Connection Interface (ADL-Rundle-6) — Detection FPS vs #NCS2",
        &hdr,
    );
    let mut sweeps = Vec::new();
    for model in [DetectorModelId::Ssd300, DetectorModelId::Yolov3] {
        for link in [LinkProfile::usb2(), LinkProfile::usb3()] {
            let s = sweep(model, link.clone(), 7, seed);
            let mut row = vec![model.label().to_string(), link.name.to_string()];
            for (_, fps) in &s.by_n {
                row.push(f(*fps, 1));
            }
            t.row(row);
            sweeps.push(s);
        }
    }
    (t, sweeps)
}

/// Extension: σ_P for 7 sticks across the whole Table VIII link registry
/// (what §IV-D's 5G/10GbE discussion projects for multi-node fleets).
pub fn link_projection(seed: u64) -> (Table, Vec<(String, f64)>) {
    let clip = generate(&presets::adl_rundle6(seed), None);
    let mut t = Table::new(
        "Link projection: YOLOv3, 7 devices, shared link (extends Table VIII)",
        &["Link", "Nominal", "Effective", "σ_P (FPS)"],
    );
    let mut out = Vec::new();
    for link in LinkProfile::registry() {
        let fleet = Fleet::ncs2_sticks(7, DetectorModelId::Yolov3, link.clone());
        let fps = saturated_fps(&clip, &fleet, SchedulerKind::Fcfs, seed + 3);
        t.row(vec![
            link.name.to_string(),
            format!("{:.1} Gbps", link.nominal_bps / 1e9),
            format!("{:.2} Gbps", link.effective_bps() / 1e9),
            f(fps, 1),
        ]);
        out.push((link.name.to_string(), fps));
    }
    (t, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn usb3_beats_usb2_everywhere() {
        let u2 = sweep(DetectorModelId::Yolov3, LinkProfile::usb2(), 3, 1);
        let u3 = sweep(DetectorModelId::Yolov3, LinkProfile::usb3(), 3, 1);
        for n in 0..3 {
            assert!(u3.by_n[n].1 > u2.by_n[n].1, "n={}", n + 1);
        }
    }

    #[test]
    fn yolo_usb2_plateaus_ssd_does_not() {
        // Table IX's signature: the larger YOLO payload saturates the
        // USB 2.0 bus near n=5 while SSD keeps scaling to n=7.
        let yolo = sweep(DetectorModelId::Yolov3, LinkProfile::usb2(), 7, 2);
        let ssd = sweep(DetectorModelId::Ssd300, LinkProfile::usb2(), 7, 2);
        let yolo_gain_57 = yolo.by_n[6].1 - yolo.by_n[4].1;
        let ssd_gain_57 = ssd.by_n[6].1 - ssd.by_n[4].1;
        assert!(yolo_gain_57 < 0.5, "yolo gain n5->n7 {yolo_gain_57}");
        assert!(ssd_gain_57 > 2.0, "ssd gain n5->n7 {ssd_gain_57}");
        // Plateau level near the paper's ~8 FPS.
        assert!((yolo.by_n[6].1 - 8.0).abs() < 0.6, "{}", yolo.by_n[6].1);
    }

    #[test]
    fn single_stick_rates_match_table9() {
        let yolo2 = sweep(DetectorModelId::Yolov3, LinkProfile::usb2(), 1, 3);
        let ssd2 = sweep(DetectorModelId::Ssd300, LinkProfile::usb2(), 1, 3);
        assert!((yolo2.by_n[0].1 - 1.9).abs() < 0.15, "{}", yolo2.by_n[0].1);
        assert!((ssd2.by_n[0].1 - 2.0).abs() < 0.15, "{}", ssd2.by_n[0].1);
    }
}
