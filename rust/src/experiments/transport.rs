//! Transport sweeps: the cross-host co-simulation against its
//! in-process twin (see EXPERIMENTS.md §Transport for the measured
//! numbers).
//!
//! * [`loopback_parity`] — the acceptance sweep: the same 2-shard
//!   balanced scenario run in-process, over loopback TCP, and over
//!   Unix-domain sockets. The remote coordinator mirrors the in-process
//!   epoch arithmetic and seeds, so delivered FPS must land within 5%
//!   (in practice it is exact on failure-free runs — the transport adds
//!   wall-clock cost, not virtual-time cost).
//! * [`connection_loss`] — a shard's socket dies mid-run (no goodbye):
//!   peer loss surfaces as shard loss, and every orphaned stream is
//!   re-placed on the survivors within one gossip interval.

use std::collections::BTreeMap;

use crate::experiments::fleet::pool_of;
use crate::fleet::admission::AdmissionPolicy;
use crate::fleet::stream::StreamSpec;
use crate::shard::remote::{run_sharded_remote, RemoteTransport};
use crate::shard::sim::{run_sharded, ShardScenario};
use crate::util::json::Json;
use crate::util::table::{f, Table};

/// One transport's outcome on the parity scenario.
#[derive(Debug, Clone)]
pub struct ParityOutcome {
    /// "inproc", "tcp" or "uds".
    pub transport: &'static str,
    pub delivered_fps: f64,
    pub drop_rate: f64,
    /// Delivered FPS relative to the in-process co-simulation.
    pub vs_inproc: f64,
    /// Control events routed (all of them crossed the wire for the
    /// socket transports).
    pub control_events: usize,
}

/// The shared parity scenario: 8 × 10-FPS streams saturating 2 shards ×
/// 4 × 2.5-FPS devices (Σμ = 20), least-loaded placement, 5 gossip
/// epochs of 10 s.
fn parity_scenario(seed: u64) -> ShardScenario {
    let streams: Vec<StreamSpec> = (0..8)
        .map(|i| StreamSpec::new(&format!("cam{i}"), 10.0, 300).with_window(4))
        .collect();
    ShardScenario::builder(vec![pool_of(4, 2.5), pool_of(4, 2.5)], streams)
        .admission(AdmissionPolicy::admit_all())
        .gossip(10.0)
        .epochs(5)
        .seed(seed)
        .build()
}

/// Parity sweep: in-process vs loopback TCP vs Unix-domain sockets on
/// the same 2-shard scenario.
pub fn loopback_parity(seed: u64) -> (Table, Vec<ParityOutcome>) {
    let mut t = Table::new(
        "Transport parity (8 × 10-FPS streams over 2 shards, Σμ = 20)",
        &["transport", "delivered σ", "vs in-process", "drop %", "control events"],
    );
    let scenario = parity_scenario(seed);
    let inproc = run_sharded(&scenario);
    let mut outcomes = Vec::new();
    let baseline = inproc.delivered_fps();
    for (transport, report) in [
        ("inproc", inproc),
        (
            "tcp",
            run_sharded_remote(&scenario, RemoteTransport::Tcp)
                .expect("loopback TCP co-simulation"),
        ),
        (
            "uds",
            run_sharded_remote(&scenario, RemoteTransport::Uds)
                .expect("Unix-socket co-simulation"),
        ),
    ] {
        let outcome = ParityOutcome {
            transport,
            delivered_fps: report.delivered_fps(),
            drop_rate: report.drop_rate(),
            vs_inproc: report.delivered_fps() / baseline.max(1e-9),
            control_events: report.control_log.len(),
        };
        t.row(vec![
            outcome.transport.to_string(),
            f(outcome.delivered_fps, 2),
            f(outcome.vs_inproc, 3),
            f(outcome.drop_rate * 100.0, 1),
            format!("{}", outcome.control_events),
        ]);
        outcomes.push(outcome);
    }
    (t, outcomes)
}

/// One transport's outcome on the sharded-autoscale overload scenario.
#[derive(Debug, Clone)]
pub struct AutoscaleParityOutcome {
    /// "inproc", "tcp" or "uds".
    pub transport: &'static str,
    pub frames_total: u64,
    pub frames_processed: u64,
    pub migrations: usize,
    /// Shard-local scale actions in the coordinator's audit log.
    pub scale_actions: usize,
    /// All routed control events (placement + scale).
    pub control_events: usize,
}

/// JSON row for one [`AutoscaleParityOutcome`] (shared with the
/// `eva shard --autoscale --json` bundle).
pub fn autoscale_parity_json(o: &AutoscaleParityOutcome) -> Json {
    let mut m = BTreeMap::new();
    m.insert("transport".into(), Json::Str(o.transport.to_string()));
    m.insert("frames_total".into(), Json::Num(o.frames_total as f64));
    m.insert(
        "frames_processed".into(),
        Json::Num(o.frames_processed as f64),
    );
    m.insert("migrations".into(), Json::Num(o.migrations as f64));
    m.insert("scale_actions".into(), Json::Num(o.scale_actions as f64));
    m.insert("control_events".into(), Json::Num(o.control_events as f64));
    Json::Obj(m)
}

/// Autoscale parity sweep: the sharded-autoscale overload scenario
/// ([`crate::experiments::shard::overload_scenario`]) run in-process
/// and with every shard behind a loopback TCP / Unix socket. The
/// autoscale config crosses the handshake and every scale action rides
/// a control frame back, so frame and scale-action counts must match
/// the in-process co-simulation *exactly* on these failure-free runs.
pub fn autoscale_parity(seed: u64) -> (Table, Vec<AutoscaleParityOutcome>) {
    let scenario = crate::experiments::shard::overload_scenario(seed, true);
    let mut t = Table::new(
        "Sharded-autoscale parity (2× overload, local scaling on): inproc vs tcp vs uds",
        &["transport", "frames", "processed", "migrations", "scale actions", "control events"],
    );
    let mut outcomes = Vec::new();
    for (transport, report) in [
        ("inproc", run_sharded(&scenario)),
        (
            "tcp",
            run_sharded_remote(&scenario, RemoteTransport::Tcp)
                .expect("loopback TCP autoscale co-simulation"),
        ),
        (
            "uds",
            run_sharded_remote(&scenario, RemoteTransport::Uds)
                .expect("Unix-socket autoscale co-simulation"),
        ),
    ] {
        let outcome = AutoscaleParityOutcome {
            transport,
            frames_total: report.total_frames(),
            frames_processed: report.total_processed(),
            migrations: report.migrations,
            scale_actions: report.scale_actions(),
            control_events: report.control_log.len(),
        };
        t.row(vec![
            outcome.transport.to_string(),
            format!("{}", outcome.frames_total),
            format!("{}", outcome.frames_processed),
            format!("{}", outcome.migrations),
            format!("{}", outcome.scale_actions),
            format!("{}", outcome.control_events),
        ]);
        outcomes.push(outcome);
    }
    (t, outcomes)
}

/// Connection-loss outcome over loopback TCP.
#[derive(Debug, Clone)]
pub struct LossOutcome {
    pub orphans: usize,
    pub replaced_within_interval: bool,
    pub worst_gap: f64,
    pub delivered_fps: f64,
    pub drop_rate: f64,
    pub shards_alive: usize,
}

/// A shard's connection dies mid-run (scripted drop, no goodbye): 9 ×
/// 2.5-FPS streams on 3 shards over loopback TCP; shard 0's socket
/// drops at epoch 2. Its three residents must be re-placed on the
/// survivors within one gossip interval.
pub fn connection_loss(seed: u64) -> (Table, LossOutcome) {
    let streams: Vec<StreamSpec> = (0..9)
        .map(|i| StreamSpec::new(&format!("cam{i}"), 2.5, 200).with_window(4))
        .collect();
    let scenario = ShardScenario::builder(
        vec![pool_of(4, 2.5), pool_of(4, 2.5), pool_of(4, 2.5)],
        streams,
    )
    .gossip(10.0)
    .epochs(10)
    .seed(seed)
    .failure(2, 0)
    .build();
    let report = run_sharded_remote(&scenario, RemoteTransport::Tcp)
        .expect("loopback TCP co-simulation");
    let outcome = LossOutcome {
        orphans: report.orphan_count(),
        replaced_within_interval: report.orphans_replaced_within(report.gossip_interval),
        worst_gap: report.worst_orphan_gap(),
        delivered_fps: report.delivered_fps(),
        drop_rate: report.drop_rate(),
        shards_alive: report.shard_alive.iter().filter(|&&a| a).count(),
    };
    let mut t = Table::new(
        "Connection loss over TCP (1 of 3 shard sockets dies at epoch 2)",
        &["orphans", "re-placed ≤ 1 interval", "worst gap (s)", "delivered σ", "drop %", "shards alive"],
    );
    t.row(vec![
        format!("{}", outcome.orphans),
        if outcome.replaced_within_interval { "yes" } else { "no" }.to_string(),
        f(outcome.worst_gap, 1),
        f(outcome.delivered_fps, 2),
        f(outcome.drop_rate * 100.0, 1),
        format!("{}", outcome.shards_alive),
    ]);
    (t, outcome)
}

/// Machine-readable sweep results (the `eva shard --scenario transport
/// --json` surface); `None` for an unknown scenario name.
pub fn transport_json(seed: u64, scenario: &str) -> Option<Json> {
    if !matches!(scenario, "parity" | "loss" | "autoscale" | "all") {
        return None;
    }
    let mut root = BTreeMap::new();
    root.insert("seed".into(), Json::Num(seed as f64));
    if matches!(scenario, "autoscale" | "all") {
        let (_, parity) = autoscale_parity(seed);
        root.insert(
            "autoscale_parity".into(),
            Json::Arr(parity.iter().map(autoscale_parity_json).collect()),
        );
    }
    if matches!(scenario, "parity" | "all") {
        let (_, parity) = loopback_parity(seed);
        let rows: Vec<Json> = parity
            .iter()
            .map(|o| {
                let mut m = BTreeMap::new();
                m.insert("transport".into(), Json::Str(o.transport.to_string()));
                m.insert("delivered_fps".into(), Json::Num(o.delivered_fps));
                m.insert("vs_inproc".into(), Json::Num(o.vs_inproc));
                m.insert("drop_rate".into(), Json::Num(o.drop_rate));
                m.insert(
                    "control_events".into(),
                    Json::Num(o.control_events as f64),
                );
                Json::Obj(m)
            })
            .collect();
        root.insert("loopback_parity".into(), Json::Arr(rows));
    }
    if matches!(scenario, "loss" | "all") {
        let (_, o) = connection_loss(seed);
        let mut m = BTreeMap::new();
        m.insert("orphans".into(), Json::Num(o.orphans as f64));
        m.insert(
            "replaced_within_interval".into(),
            Json::Bool(o.replaced_within_interval),
        );
        m.insert("worst_gap".into(), Json::Num(o.worst_gap));
        m.insert("delivered_fps".into(), Json::Num(o.delivered_fps));
        m.insert("drop_rate".into(), Json::Num(o.drop_rate));
        m.insert("shards_alive".into(), Json::Num(o.shards_alive as f64));
        root.insert("connection_loss".into(), Json::Obj(m));
    }
    Some(Json::Obj(root))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_transports_match_inproc_within_5_percent() {
        // The acceptance criterion: a 2-shard run over loopback TCP (and
        // UDS) matches the in-process co-simulation's delivered FPS
        // within 5% at equal capacity.
        let (_, outcomes) = loopback_parity(73);
        assert_eq!(outcomes[0].transport, "inproc");
        for o in &outcomes[1..] {
            assert!(
                (o.vs_inproc - 1.0).abs() < 0.05,
                "{}: σ {:.2} is {:.3}× in-process",
                o.transport,
                o.delivered_fps,
                o.vs_inproc
            );
            assert!(o.control_events >= 8, "{}: {} events", o.transport, o.control_events);
        }
    }

    #[test]
    fn connection_loss_replaces_orphans_within_one_interval() {
        // The acceptance criterion: killing one shard's connection
        // re-places all its orphaned streams within one gossip interval.
        let (_, o) = connection_loss(79);
        assert_eq!(o.orphans, 3, "{o:?}");
        assert!(o.replaced_within_interval, "{o:?}");
        assert!(o.worst_gap <= 10.0 + 1e-9, "{o:?}");
        assert_eq!(o.shards_alive, 2);
    }

    #[test]
    fn autoscale_parity_is_exact_across_transports() {
        // The acceptance criterion: the sharded-autoscale run behaves
        // identically over inproc, tcp and uds — frame and scale-action
        // counts match exactly on a failure-free run.
        let (_, outcomes) = autoscale_parity(91);
        assert_eq!(outcomes.len(), 3);
        let inproc = &outcomes[0];
        assert_eq!(inproc.transport, "inproc");
        assert_eq!(inproc.migrations, 0, "{inproc:?}");
        assert!(inproc.scale_actions >= 1, "{inproc:?}");
        for o in &outcomes[1..] {
            assert_eq!(o.frames_total, inproc.frames_total, "{}", o.transport);
            assert_eq!(o.frames_processed, inproc.frames_processed, "{}", o.transport);
            assert_eq!(o.migrations, inproc.migrations, "{}", o.transport);
            assert_eq!(o.scale_actions, inproc.scale_actions, "{}", o.transport);
        }
        // The socket transports agree with *each other* on the whole
        // routed-event count too. (The remote runner additionally logs
        // the played-out detaches it must ship so shard-side digests
        // stay honest — events the in-process runner never needs — so
        // total event counts are only comparable remote-to-remote.)
        assert_eq!(outcomes[1].control_events, outcomes[2].control_events);
    }

    #[test]
    fn json_bundle_reparses_and_respects_scenario_selection() {
        let j = transport_json(5, "parity").expect("known scenario");
        let back = Json::parse(&j.to_string()).expect("transport JSON must reparse");
        assert_eq!(back.get("seed").and_then(Json::as_i64), Some(5));
        assert_eq!(
            back.get("loopback_parity").unwrap().as_arr().unwrap().len(),
            3
        );
        assert!(back.get("connection_loss").is_none());
        assert!(back.get("autoscale_parity").is_none());
        let aut = transport_json(5, "autoscale").expect("known scenario");
        assert_eq!(
            aut.get("autoscale_parity").unwrap().as_arr().unwrap().len(),
            3
        );
        assert!(aut.get("loopback_parity").is_none());
        assert!(transport_json(5, "bogus").is_none());
    }
}
