//! Forecast-fusion sweeps: reactive vs forecast-fused control on a
//! diurnal load, plus the offline deployment-space search (see
//! EXPERIMENTS.md §Forecast for the measured numbers).
//!
//! * [`diurnal_sweep`] — the acceptance sweep: the same day-shaped
//!   square-wave load (two low epochs, two ×2 epochs, repeating) served
//!   by identical autoscaled shards with and without the forecast layer
//!   ([`crate::forecast`]), in the in-process co-simulation **and** over
//!   loopback TCP. Reactive control only sees the ramp after it has
//!   landed: every device attach fires inside a high phase, after the
//!   breach already cost dropped frames. Fused control learns the shape
//!   after a couple of cycles and attaches *inside the low phase right
//!   before the ramp* — the pre-ramp attach the paper's
//!   arrival-vs-processing-rate mismatch (§ III) calls for.
//! * [`deployment_search`] — AyE-Edge-style offline search: sweep
//!   (devices per shard, model ladder, placement policy, autoscale p99
//!   band) per load scenario over the virtual-time engine, score every
//!   cell, and emit the recommended deployment as JSON (the `eva
//!   forecast --json` surface, uploaded by CI as `BENCH_forecast.json`).
//!
//! Delivered quality here is the shard-level analytic mAP proxy
//! ([`delivered_quality`]): sharded runs keep per-stream frame counters
//! and the routed control log, not per-record detection output, so each
//! processed frame contributes its stream's current ladder-rung quality
//! ([`ModelLadder::quality`], rung timeline reconstructed from the
//! audited `SwapModel` events) and every dropped frame contributes
//! zero. It is a proxy with the same calibrated anchors as the fleet
//! sweeps, not an mAP measurement.

use crate::autoscale::ladder::ModelLadder;
use crate::autoscale::policy::AutoscaleConfig;
use crate::control::{ControlAction, ControlOrigin};
use crate::experiments::fleet::pool_of;
use crate::fleet::stream::{RateProfile, StreamSpec};
use crate::forecast::{forecast_config_to_json, ForecastConfig};
use crate::shard::placement::PlacementPolicy;
use crate::shard::remote::{run_sharded_remote, RemoteTransport};
use crate::shard::sim::{run_sharded, ShardReport, ShardScenario};
use crate::util::json::Json;
use crate::util::table::{f, Table};
use std::collections::BTreeMap;

/// Gossip interval of every forecast sweep (seconds). The diurnal
/// profile buckets are aligned to it so each gossip epoch sits entirely
/// inside one phase of the day shape.
pub const FORECAST_GOSSIP: f64 = 5.0;

/// Diurnal cycle length in seconds: four gossip epochs — two low, two
/// high — so [`forecast_tuning`]'s seasonal period of 4 observes one
/// bucket per epoch.
pub const DIURNAL_CYCLE: f64 = 20.0;

/// Epochs of the acceptance sweep (six full diurnal cycles: the
/// forecaster needs two to three cycles of scored residuals before its
/// confidence band tightens, leaving several cycles of fused control).
pub const DIURNAL_EPOCHS: usize = 24;

/// Per-camera base rate (FPS) and the peak multiplier of the high
/// phase. Six cameras over two shards: committed Σλ per shard swings
/// 4.2 → 8.4 FPS against a 3 × 2.5-FPS seed pool, so the high phase
/// breaches admission capacity until the autoscaler attaches.
pub const DIURNAL_BASE_FPS: f64 = 1.4;
pub const DIURNAL_PEAK_MULT: f64 = 2.0;
pub const DIURNAL_CAMS: usize = 6;

/// The day shape: two low buckets then two ×2 buckets per cycle.
pub fn diurnal_profile() -> RateProfile {
    RateProfile::new(
        DIURNAL_CYCLE,
        vec![1.0, 1.0, DIURNAL_PEAK_MULT, DIURNAL_PEAK_MULT],
    )
}

/// The forecast tuning every sweep runs: seasonal period matched to the
/// four-epoch cycle, horizon 2 so the prediction armed while serving
/// epoch *e* covers epoch *e + 1* (the pre-ramp lead), and a band gate
/// loose enough that the square wave's persistent EWMA residual still
/// qualifies as tight once the shape is learned.
pub fn forecast_tuning() -> ForecastConfig {
    ForecastConfig {
        alpha: 0.3,
        season_alpha: 0.3,
        period: 4,
        horizon: 2,
        band: 0.75,
        hold_window: 2,
    }
}

/// Shard-local scaling of the sweeps: 2.5-FPS template replicas up to
/// twice the seed pool, with a short cooldown so the forecast hint can
/// finish pre-provisioning inside one low epoch.
fn diurnal_autoscale() -> AutoscaleConfig {
    AutoscaleConfig {
        device_rate: 2.5,
        max_devices: 6,
        cooldown: 2.0,
        ..AutoscaleConfig::default()
    }
}

/// The acceptance scenario: six diurnal cameras over two autoscaled
/// shards; `fused` arms the forecast layer (everything else identical,
/// so the delta is purely the predicted-Σλ signal).
pub fn diurnal_scenario(seed: u64, fused: bool) -> ShardScenario {
    let profile = diurnal_profile();
    let streams: Vec<StreamSpec> = (0..DIURNAL_CAMS)
        .map(|i| {
            StreamSpec::new(&format!("cam{i}"), DIURNAL_BASE_FPS, 400)
                .with_window(4)
                .with_profile(profile.clone())
        })
        .collect();
    let builder = ShardScenario::builder(vec![pool_of(3, 2.5), pool_of(3, 2.5)], streams)
        .policy(PlacementPolicy::LeastLoaded)
        .gossip(FORECAST_GOSSIP)
        .epochs(DIURNAL_EPOCHS)
        .seed(seed)
        .autoscale(diurnal_autoscale());
    if fused {
        builder.forecast(forecast_tuning()).build()
    } else {
        builder.build()
    }
}

/// Controller device attaches split by the diurnal phase they fired in:
/// a low-phase attach provisions *ahead* of the ramp (only a forecast
/// hint can cause one — reactive control has no breach signal to act on
/// while the load is low), a high-phase attach is reactive repair after
/// the step already landed. Returns `(pre_ramp, post_step)`.
pub fn attach_phases(report: &ShardReport) -> (usize, usize) {
    let profile = diurnal_profile();
    let mut pre = 0usize;
    let mut post = 0usize;
    for c in &report.control_log {
        if c.event.origin != ControlOrigin::Controller {
            continue;
        }
        if let Some(ControlAction::AttachDevice(_)) = c.event.as_action() {
            if profile.multiplier_at(c.event.at) <= 1.0 + 1e-9 {
                pre += 1;
            } else {
                post += 1;
            }
        }
    }
    (pre, post)
}

/// Shard-level delivered-quality proxy (analytic delivered mAP): each
/// processed frame contributes its stream's ladder-rung quality at the
/// time it was served — the rung timeline reconstructed from the routed
/// `SwapModel` audit events (rung 0 until the first swap) — and every
/// dropped frame contributes zero. Frame-weighted over all arrivals.
pub fn delivered_quality(report: &ShardReport, ladder: &ModelLadder) -> f64 {
    let end = report.makespan();
    let mut total = 0.0;
    let mut frames = 0u64;
    for (i, s) in report.streams.iter().enumerate() {
        let mut swaps: Vec<(f64, usize)> = report
            .control_log
            .iter()
            .filter_map(|c| match c.event.as_action() {
                Some(ControlAction::SwapModel { stream, rung }) if *stream == i => {
                    Some((c.event.at, *rung))
                }
                _ => None,
            })
            .collect();
        swaps.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
        // Time-weighted mean rung quality stands in for frame-weighted:
        // the per-epoch frame quota tracks the offered rate, so epochs
        // weigh in proportion to the frames they served.
        let mean_q = if end > 0.0 {
            let mut q_time = 0.0;
            let mut t_prev = 0.0;
            let mut rung = 0usize;
            for (t, r) in swaps {
                q_time += ladder.quality(rung) * (t.min(end) - t_prev).max(0.0);
                t_prev = t.min(end);
                rung = r;
            }
            q_time += ladder.quality(rung) * (end - t_prev).max(0.0);
            q_time / end
        } else {
            ladder.quality(0)
        };
        total += mean_q * s.frames_processed as f64;
        frames += s.frames_total;
    }
    if frames == 0 {
        0.0
    } else {
        total / frames as f64
    }
}

/// One mode × runner cell of the diurnal acceptance sweep.
#[derive(Debug, Clone)]
pub struct DiurnalOutcome {
    /// "reactive" or "fused".
    pub mode: &'static str,
    /// "inproc" or "tcp".
    pub runner: &'static str,
    pub migrations: usize,
    pub scale_actions: usize,
    /// Device attaches inside a low phase (provisioned ahead of the ramp).
    pub pre_ramp_attaches: usize,
    /// Device attaches inside a high phase (reactive repair).
    pub post_step_attaches: usize,
    pub worst_p99: f64,
    pub drop_rate: f64,
    /// Analytic delivered-mAP proxy ([`delivered_quality`]).
    pub delivered_quality: f64,
    /// Forecast-Σλ slots that rode gossip digests (0 in reactive mode).
    pub forecast_digests: usize,
}

fn diurnal_outcome(
    mode: &'static str,
    runner: &'static str,
    report: &ShardReport,
    ladder: &ModelLadder,
) -> DiurnalOutcome {
    let (pre, post) = attach_phases(report);
    DiurnalOutcome {
        mode,
        runner,
        migrations: report.migrations,
        scale_actions: report.scale_actions(),
        pre_ramp_attaches: pre,
        post_step_attaches: post,
        worst_p99: report.worst_p99(),
        drop_rate: report.drop_rate(),
        delivered_quality: delivered_quality(report, ladder),
        forecast_digests: report.forecast_trace.len(),
    }
}

/// The diurnal acceptance sweep: reactive vs fused, each in the
/// in-process co-simulation and over loopback TCP (four runs). The
/// fused cells must place their first attach of a cycle *before* the
/// ramp once the shape is learned; the reactive cells never can.
pub fn diurnal_sweep(seed: u64) -> (Table, Vec<DiurnalOutcome>) {
    let ladder = ModelLadder::from_profiles("eth_sunnyday");
    let mut t = Table::new(
        "Diurnal ramp (Σλ 8.4 → 16.8 FPS): reactive vs forecast-fused control",
        &[
            "mode", "runner", "migrations", "scale actions", "pre-ramp attach",
            "post-step attach", "worst p99 (s)", "drop %", "delivered mAP*",
        ],
    );
    let mut outcomes = Vec::new();
    for (mode, fused) in [("reactive", false), ("fused", true)] {
        let scenario = diurnal_scenario(seed, fused);
        for (runner, report) in [
            ("inproc", run_sharded(&scenario)),
            (
                "tcp",
                run_sharded_remote(&scenario, RemoteTransport::Tcp)
                    .expect("loopback TCP forecast co-simulation"),
            ),
        ] {
            let o = diurnal_outcome(mode, runner, &report, &ladder);
            t.row(vec![
                o.mode.to_string(),
                o.runner.to_string(),
                format!("{}", o.migrations),
                format!("{}", o.scale_actions),
                format!("{}", o.pre_ramp_attaches),
                format!("{}", o.post_step_attaches),
                f(o.worst_p99, 2),
                f(o.drop_rate * 100.0, 1),
                f(o.delivered_quality * 100.0, 1),
            ]);
            outcomes.push(o);
        }
    }
    (t, outcomes)
}

/// Provisioning cost per device slot in the deployment score: quality
/// points a deployment must earn back per device per shard, so the
/// search does not trivially recommend the biggest pool.
pub const SEARCH_DEVICE_COST: f64 = 0.012;
/// Score penalty per completed migration (placement churn is not free).
pub const SEARCH_MIGRATION_COST: f64 = 0.004;
/// Epochs per search cell (five diurnal cycles — enough for the
/// forecast to warm up and the deployment differences to show).
pub const SEARCH_EPOCHS: usize = 20;

/// The load scenarios the deployment space is searched under.
pub const SEARCH_SCENARIOS: [&str; 2] = ["diurnal", "burst"];
/// Devices per shard sweep.
pub const SEARCH_DEVICES: [usize; 3] = [2, 3, 4];
/// Autoscale p99-band sweep (seconds).
pub const SEARCH_BANDS: [f64; 2] = [1.5, 3.0];

/// Load shape per search scenario: "diurnal" is the acceptance ramp,
/// "burst" a one-epoch ×2 spike per cycle — the transient the admission
/// hold ([`crate::forecast::should_hold`]) is designed to ride out.
pub fn search_profile(scenario: &str) -> RateProfile {
    match scenario {
        "burst" => RateProfile::new(DIURNAL_CYCLE, vec![1.0, 1.0, 1.0, DIURNAL_PEAK_MULT]),
        _ => diurnal_profile(),
    }
}

/// One evaluated deployment cell.
#[derive(Debug, Clone)]
pub struct SearchPoint {
    pub scenario: &'static str,
    pub devices_per_shard: usize,
    /// Ladder preset name, "none" for device-only scaling.
    pub ladder: &'static str,
    pub policy: &'static str,
    /// Autoscale p99 bound (seconds) — the band dimension.
    pub band: f64,
    pub migrations: usize,
    pub scale_actions: usize,
    pub worst_p99: f64,
    pub drop_rate: f64,
    pub delivered_quality: f64,
    /// `delivered_quality − SEARCH_DEVICE_COST·n − SEARCH_MIGRATION_COST·migrations`.
    pub score: f64,
}

fn search_cell(
    seed: u64,
    scenario: &'static str,
    devices: usize,
    ladder_name: &'static str,
    ladder: Option<&ModelLadder>,
    policy: PlacementPolicy,
    policy_name: &'static str,
    band: f64,
) -> SearchPoint {
    let profile = search_profile(scenario);
    let streams: Vec<StreamSpec> = (0..DIURNAL_CAMS)
        .map(|i| {
            StreamSpec::new(&format!("cam{i}"), DIURNAL_BASE_FPS, 400)
                .with_window(4)
                .with_profile(profile.clone())
        })
        .collect();
    let mut cfg = AutoscaleConfig {
        p99_bound: band,
        device_rate: 2.5,
        max_devices: devices * 2,
        cooldown: 2.0,
        ..AutoscaleConfig::default()
    };
    if let Some(l) = ladder {
        cfg = cfg.with_ladder(l.clone());
    }
    // Ladder cells degrade by model swap at admission time; the others
    // keep the default stride degradation.
    let admission = cfg.admission();
    let scenario_built =
        ShardScenario::builder(vec![pool_of(devices, 2.5), pool_of(devices, 2.5)], streams)
            .policy(policy)
            .admission(admission)
            .gossip(FORECAST_GOSSIP)
            .epochs(SEARCH_EPOCHS)
            .seed(seed)
            .autoscale(cfg)
            .forecast(forecast_tuning())
            .build();
    let report = run_sharded(&scenario_built);
    let reference = ModelLadder::from_profiles("eth_sunnyday");
    let quality = delivered_quality(&report, ladder.unwrap_or(&reference));
    let score = quality
        - SEARCH_DEVICE_COST * devices as f64
        - SEARCH_MIGRATION_COST * report.migrations as f64;
    SearchPoint {
        scenario,
        devices_per_shard: devices,
        ladder: ladder_name,
        policy: policy_name,
        band,
        migrations: report.migrations,
        scale_actions: report.scale_actions(),
        worst_p99: report.worst_p99(),
        drop_rate: report.drop_rate(),
        delivered_quality: quality,
        score,
    }
}

/// The full deployment-space search: every (n, ladder, policy, band)
/// cell under every load scenario, scored in virtual time. Returns the
/// table of per-scenario recommendations plus every evaluated cell.
pub fn deployment_search(seed: u64) -> (Table, Vec<SearchPoint>) {
    let eth = ModelLadder::from_profiles("eth_sunnyday");
    let ladders: [(&'static str, Option<&ModelLadder>); 2] =
        [("none", None), ("eth_sunnyday", Some(&eth))];
    let policies = [
        (PlacementPolicy::LeastLoaded, "least-loaded"),
        (PlacementPolicy::RoundRobin, "round-robin"),
        (PlacementPolicy::Hash, "hash"),
    ];
    let mut points = Vec::new();
    for &scenario in &SEARCH_SCENARIOS {
        for &devices in &SEARCH_DEVICES {
            for &(ladder_name, ladder) in &ladders {
                for &(policy, policy_name) in &policies {
                    for &band in &SEARCH_BANDS {
                        points.push(search_cell(
                            seed, scenario, devices, ladder_name, ladder, policy,
                            policy_name, band,
                        ));
                    }
                }
            }
        }
    }
    let mut t = Table::new(
        "Deployment-space search (n × ladder × policy × band), forecast-fused",
        &[
            "scenario", "cells", "best n/shard", "ladder", "policy", "band (s)",
            "delivered mAP*", "score",
        ],
    );
    for &scenario in &SEARCH_SCENARIOS {
        let best = recommended(&points, scenario).expect("non-empty grid");
        let cells = points.iter().filter(|p| p.scenario == scenario).count();
        t.row(vec![
            scenario.to_string(),
            format!("{cells}"),
            format!("{}", best.devices_per_shard),
            best.ladder.to_string(),
            best.policy.to_string(),
            f(best.band, 1),
            f(best.delivered_quality * 100.0, 1),
            f(best.score, 3),
        ]);
    }
    (t, points)
}

/// The recommended cell for one scenario: highest score, ties broken by
/// grid order (fewest devices first — the grid ascends in n), so the
/// recommendation is deterministic for a deterministic engine.
pub fn recommended<'a>(points: &'a [SearchPoint], scenario: &str) -> Option<&'a SearchPoint> {
    let mut best: Option<&SearchPoint> = None;
    for p in points.iter().filter(|p| p.scenario == scenario) {
        match best {
            None => best = Some(p),
            Some(b) if p.score > b.score + 1e-12 => best = Some(p),
            Some(_) => {}
        }
    }
    best
}

fn outcome_json(o: &DiurnalOutcome) -> Json {
    let mut m = BTreeMap::new();
    m.insert("mode".into(), Json::Str(o.mode.to_string()));
    m.insert("runner".into(), Json::Str(o.runner.to_string()));
    m.insert("migrations".into(), Json::Num(o.migrations as f64));
    m.insert("scale_actions".into(), Json::Num(o.scale_actions as f64));
    m.insert("pre_ramp_attaches".into(), Json::Num(o.pre_ramp_attaches as f64));
    m.insert("post_step_attaches".into(), Json::Num(o.post_step_attaches as f64));
    m.insert("worst_p99".into(), Json::Num(o.worst_p99));
    m.insert("drop_rate".into(), Json::Num(o.drop_rate));
    m.insert("delivered_quality".into(), Json::Num(o.delivered_quality));
    m.insert("forecast_digests".into(), Json::Num(o.forecast_digests as f64));
    Json::Obj(m)
}

fn point_json(p: &SearchPoint) -> Json {
    let mut m = BTreeMap::new();
    m.insert("scenario".into(), Json::Str(p.scenario.to_string()));
    m.insert("devices_per_shard".into(), Json::Num(p.devices_per_shard as f64));
    m.insert("ladder".into(), Json::Str(p.ladder.to_string()));
    m.insert("policy".into(), Json::Str(p.policy.to_string()));
    m.insert("band".into(), Json::Num(p.band));
    m.insert("migrations".into(), Json::Num(p.migrations as f64));
    m.insert("scale_actions".into(), Json::Num(p.scale_actions as f64));
    m.insert("worst_p99".into(), Json::Num(p.worst_p99));
    m.insert("drop_rate".into(), Json::Num(p.drop_rate));
    m.insert("delivered_quality".into(), Json::Num(p.delivered_quality));
    m.insert("score".into(), Json::Num(p.score));
    m.insert("forecast".into(), forecast_config_to_json(&forecast_tuning()));
    Json::Obj(m)
}

/// Machine-readable bundle (the `eva forecast --json` surface; CI
/// uploads it as `BENCH_forecast.json`): the diurnal acceptance sweep,
/// every evaluated deployment cell, and the per-scenario recommended
/// configs.
pub fn forecast_json(seed: u64) -> Json {
    let mut root = BTreeMap::new();
    root.insert("seed".into(), Json::Num(seed as f64));
    let (_, diurnal) = diurnal_sweep(seed);
    root.insert("diurnal".into(), Json::Arr(diurnal.iter().map(outcome_json).collect()));
    let (_, points) = deployment_search(seed);
    root.insert("search".into(), Json::Arr(points.iter().map(point_json).collect()));
    let mut rec = BTreeMap::new();
    for &scenario in &SEARCH_SCENARIOS {
        if let Some(best) = recommended(&points, scenario) {
            rec.insert(scenario.to_string(), point_json(best));
        }
    }
    root.insert("recommended".into(), Json::Obj(rec));
    Json::Obj(root)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance criterion, both runners in one sweep: fused
    /// control pre-provisions ahead of the ramp, never does worse than
    /// reactive on migrations, and at least matches it on the delivered
    /// quality proxy — while the tcp runner mirrors every counter of
    /// the in-process one exactly (the forecast path keeps the
    /// cross-transport parity contract).
    #[test]
    fn diurnal_fused_control_pre_provisions_and_beats_reactive() {
        let (_, outcomes) = diurnal_sweep(29);
        assert_eq!(outcomes.len(), 4);
        let get = |mode: &str, runner: &str| {
            outcomes
                .iter()
                .find(|o| o.mode == mode && o.runner == runner)
                .expect("sweep cell")
        };
        let reactive = get("reactive", "inproc");
        let fused = get("fused", "inproc");
        // The day shape must actually bite: reactive control pays at
        // least one post-step repair attach and publishes no forecasts.
        assert!(reactive.post_step_attaches >= 1, "{reactive:?}");
        assert_eq!(reactive.forecast_digests, 0, "{reactive:?}");
        // Fused control provisions ahead of the ramp (an attach inside
        // a low phase — reactive control has no signal that can do
        // that) once the seasonal shape is learned.
        assert!(fused.forecast_digests >= 1, "{fused:?}");
        assert!(
            fused.pre_ramp_attaches > reactive.pre_ramp_attaches,
            "fused {fused:?} vs reactive {reactive:?}"
        );
        // No worse on migrations, no worse on delivered quality, and no
        // post-step p99 spike beyond what reactive control pays.
        assert!(
            fused.migrations <= reactive.migrations,
            "fused {} vs reactive {}",
            fused.migrations,
            reactive.migrations
        );
        assert!(
            fused.delivered_quality >= reactive.delivered_quality - 1e-9,
            "fused {:.4} vs reactive {:.4}",
            fused.delivered_quality,
            reactive.delivered_quality
        );
        assert!(
            fused.worst_p99 <= reactive.worst_p99 + 1e-9,
            "fused p99 {:.3} vs reactive {:.3}",
            fused.worst_p99,
            reactive.worst_p99
        );
        // Both runners agree exactly, per mode — the parity contract.
        for mode in ["reactive", "fused"] {
            let a = get(mode, "inproc");
            let b = get(mode, "tcp");
            assert_eq!(a.migrations, b.migrations, "{mode}");
            assert_eq!(a.scale_actions, b.scale_actions, "{mode}");
            assert_eq!(a.pre_ramp_attaches, b.pre_ramp_attaches, "{mode}");
            assert_eq!(a.post_step_attaches, b.post_step_attaches, "{mode}");
            assert_eq!(a.forecast_digests, b.forecast_digests, "{mode}");
            assert!((a.drop_rate - b.drop_rate).abs() < 1e-12, "{mode}");
            assert!(
                (a.delivered_quality - b.delivered_quality).abs() < 1e-12,
                "{mode}"
            );
        }
    }

    #[test]
    fn deployment_search_covers_the_grid_and_recommends_a_best_cell() {
        let (_, points) = deployment_search(31);
        let per_scenario =
            SEARCH_DEVICES.len() * 2 /* ladders */ * 3 /* policies */ * SEARCH_BANDS.len();
        assert_eq!(points.len(), SEARCH_SCENARIOS.len() * per_scenario);
        for &scenario in &SEARCH_SCENARIOS {
            let best = recommended(&points, scenario).expect("recommendation");
            assert_eq!(best.scenario, scenario);
            // The recommendation is the argmax of its scenario's cells.
            for p in points.iter().filter(|p| p.scenario == scenario) {
                assert!(
                    best.score >= p.score - 1e-12,
                    "{scenario}: {best:?} not best vs {p:?}"
                );
            }
            // And it must be a deployment that actually delivers.
            assert!(best.delivered_quality > 0.0, "{best:?}");
        }
    }

    #[test]
    fn forecast_json_bundle_reparses() {
        let j = forecast_json(11);
        let back = Json::parse(&j.to_string()).expect("forecast JSON must reparse");
        assert_eq!(back.get("seed").and_then(Json::as_i64), Some(11));
        assert_eq!(back.get("diurnal").unwrap().as_arr().unwrap().len(), 4);
        let search = back.get("search").unwrap().as_arr().unwrap();
        assert!(!search.is_empty());
        let rec = back.get("recommended").unwrap();
        for scenario in SEARCH_SCENARIOS {
            let r = rec.get(scenario).expect("per-scenario recommendation");
            assert!(r.get("devices_per_shard").and_then(Json::as_i64).is_some());
            assert!(r.get("forecast").and_then(|f| f.get("period")).is_some());
        }
    }
}
