//! Churn chaos sweep: shards join and leave continuously under 2×
//! load — rolling-restart style — while the delivered-FPS floor and
//! the orphan re-placement deadline hold (see EXPERIMENTS.md §Churn
//! for the measured numbers).
//!
//! * [`churn_chaos`] — the acceptance sweep: every shard in a 3-shard
//!   fleet is restarted once (fail at epochs 2/4/6, rejoin at 4/6/8,
//!   exactly one shard down at any time) under twice the fleet's raw
//!   capacity, run in-process and with every shard behind a loopback
//!   TCP socket. Each cell must deliver at least [`CHURN_FPS_FLOOR`]
//!   of the churn-free baseline on the same load, re-place every
//!   orphan within one gossip interval, and end with all three shards
//!   back in gossip.
//!
//! The churn cells run with [`ShardScenario::handover`] on: re-placed
//! and migrated streams pay the window-rebuild toll in their reported
//! latency, so the floor prices realistic handover cost, not free
//! state teleportation.

use std::collections::BTreeMap;

use crate::experiments::fleet::pool_of;
use crate::fleet::stream::StreamSpec;
use crate::shard::remote::{run_sharded_remote, RemoteTransport};
use crate::shard::sim::{run_sharded, ShardReport, ShardScenario};
use crate::util::json::Json;
use crate::util::table::{f, Table};

/// Delivered-FPS floor under rolling restarts, as a fraction of the
/// churn-free baseline on the same 2× load. Conservative on purpose —
/// one of three shards is down for half the run, so raw capacity dips
/// to 2/3 for those epochs — but low enough that a wedged rejoin or a
/// double-placed orphan (which double-charges admission) breaks it.
pub const CHURN_FPS_FLOOR: f64 = 0.6;

/// The rolling-restart schedule: `(shard, fail epoch, rejoin epoch)`.
/// Staggered so exactly one shard is down at any time, and the last
/// rejoin (epoch 8) still leaves epochs to prove the planner re-levels
/// onto the returned capacity.
pub const CHURN_RESTARTS: [(usize, usize, usize); 3] = [(0, 2, 4), (1, 4, 6), (2, 6, 8)];

/// Gossip interval of both cells (seconds). The orphan re-placement
/// deadline is exactly one interval.
pub const CHURN_GOSSIP: f64 = 10.0;

/// 12 × 5-FPS cams = 60 FPS offered against Σμ = 30: twice the raw
/// fleet capacity, so every epoch is an overload epoch even before a
/// shard drops.
fn churn_streams() -> Vec<StreamSpec> {
    (0..12)
        .map(|i| StreamSpec::new(&format!("cam{i}"), 5.0, 600).with_window(4))
        .collect()
}

fn churn_pools() -> Vec<Vec<crate::device::DeviceInstance>> {
    vec![pool_of(4, 2.5), pool_of(4, 2.5), pool_of(4, 2.5)]
}

/// The churn-free 2×-load baseline: same pools, streams, epochs and
/// seed, no restarts. Failure-free runs are transport-exact, so one
/// in-process baseline anchors both cells.
pub fn baseline_scenario(seed: u64) -> ShardScenario {
    ShardScenario::builder(churn_pools(), churn_streams())
        .gossip(CHURN_GOSSIP)
        .epochs(12)
        .seed(seed)
        .build()
}

/// The chaos cell: the baseline plus the rolling-restart schedule,
/// with the handover toll armed.
pub fn churn_scenario(seed: u64) -> ShardScenario {
    let mut b = ShardScenario::builder(churn_pools(), churn_streams())
        .gossip(CHURN_GOSSIP)
        .epochs(12)
        .seed(seed)
        .handover();
    for &(shard, fail, rejoin) in &CHURN_RESTARTS {
        b = b.restart(shard, fail, rejoin);
    }
    b.build()
}

/// One cell's outcome under rolling restarts.
#[derive(Debug, Clone)]
pub struct ChurnOutcome {
    /// "inproc" or "tcp".
    pub mode: &'static str,
    pub delivered_fps: f64,
    /// The churn-free baseline on the same load.
    pub baseline_fps: f64,
    /// delivered / baseline — pinned ≥ [`CHURN_FPS_FLOOR`].
    pub fps_ratio: f64,
    /// Streams orphaned by any of the three losses.
    pub orphans: usize,
    /// Every orphan re-placed within one gossip interval.
    pub replaced_within_deadline: bool,
    /// Worst loss→re-placement gap (seconds).
    pub worst_gap: f64,
    pub migrations: usize,
    /// Shards in gossip at the end — all three, since every restart
    /// rejoins.
    pub shards_alive: usize,
    pub drop_rate: f64,
}

impl ChurnOutcome {
    pub fn holds_floor(&self) -> bool {
        self.fps_ratio >= CHURN_FPS_FLOOR
    }
}

fn churn_outcome(mode: &'static str, report: &ShardReport, baseline_fps: f64) -> ChurnOutcome {
    ChurnOutcome {
        mode,
        delivered_fps: report.delivered_fps(),
        baseline_fps,
        fps_ratio: report.delivered_fps() / baseline_fps.max(1e-9),
        orphans: report.orphan_count(),
        replaced_within_deadline: report.orphans_replaced_within(report.gossip_interval),
        worst_gap: report.worst_orphan_gap(),
        migrations: report.migrations,
        shards_alive: report.shard_alive.iter().filter(|&&a| a).count(),
        drop_rate: report.drop_rate(),
    }
}

/// Churn chaos sweep: rolling restarts of all three shards at 2× load,
/// in-process and over loopback TCP.
pub fn churn_chaos(seed: u64) -> (Table, Vec<ChurnOutcome>) {
    let baseline_fps = run_sharded(&baseline_scenario(seed)).delivered_fps();
    let scenario = churn_scenario(seed);
    let mut t = Table::new(
        "Rolling restarts at 2× load (3 shards, each down for 2 of 12 epochs)",
        &[
            "mode", "delivered σ", "baseline σ", "ratio", "floor ok", "orphans",
            "re-placed ≤ 1 interval", "worst gap (s)", "migrations", "shards alive",
        ],
    );
    let mut outcomes = Vec::new();
    for (mode, report) in [
        ("inproc", run_sharded(&scenario)),
        (
            "tcp",
            run_sharded_remote(&scenario, RemoteTransport::Tcp)
                .expect("loopback TCP churn co-simulation"),
        ),
    ] {
        let o = churn_outcome(mode, &report, baseline_fps);
        t.row(vec![
            o.mode.to_string(),
            f(o.delivered_fps, 2),
            f(o.baseline_fps, 2),
            f(o.fps_ratio, 3),
            if o.holds_floor() { "yes" } else { "no" }.to_string(),
            format!("{}", o.orphans),
            if o.replaced_within_deadline { "yes" } else { "no" }.to_string(),
            f(o.worst_gap, 1),
            format!("{}", o.migrations),
            format!("{}", o.shards_alive),
        ]);
        outcomes.push(o);
    }
    (t, outcomes)
}

fn churn_outcome_json(o: &ChurnOutcome) -> Json {
    let mut m = BTreeMap::new();
    m.insert("mode".into(), Json::Str(o.mode.to_string()));
    m.insert("delivered_fps".into(), Json::Num(o.delivered_fps));
    m.insert("baseline_fps".into(), Json::Num(o.baseline_fps));
    m.insert("fps_ratio".into(), Json::Num(o.fps_ratio));
    m.insert("holds_floor".into(), Json::Bool(o.holds_floor()));
    m.insert("orphans".into(), Json::Num(o.orphans as f64));
    m.insert(
        "replaced_within_deadline".into(),
        Json::Bool(o.replaced_within_deadline),
    );
    m.insert("worst_gap".into(), Json::Num(o.worst_gap));
    m.insert("migrations".into(), Json::Num(o.migrations as f64));
    m.insert("shards_alive".into(), Json::Num(o.shards_alive as f64));
    m.insert("drop_rate".into(), Json::Num(o.drop_rate));
    Json::Obj(m)
}

/// Machine-readable churn bundle (the `eva shard --scenario churn
/// --json` surface).
pub fn churn_json(seed: u64) -> Json {
    let mut root = BTreeMap::new();
    root.insert("seed".into(), Json::Num(seed as f64));
    root.insert("fps_floor".into(), Json::Num(CHURN_FPS_FLOOR));
    root.insert("deadline_intervals".into(), Json::Num(1.0));
    let (_, outcomes) = churn_chaos(seed);
    root.insert(
        "churn_chaos".into(),
        Json::Arr(outcomes.iter().map(churn_outcome_json).collect()),
    );
    Json::Obj(root)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn churn_holds_the_floor_and_replaces_every_orphan_in_both_modes() {
        // The acceptance criterion: rolling restarts at 2× load hold
        // the pinned FPS floor, every orphan is re-placed within one
        // gossip interval, and all three shards end up back in gossip.
        let (_, outcomes) = churn_chaos(137);
        assert_eq!(outcomes.len(), 2);
        for o in &outcomes {
            assert!(o.holds_floor(), "{o:?}");
            assert!(o.orphans > 0, "{o:?}");
            assert!(o.replaced_within_deadline, "{o:?}");
            assert!(o.worst_gap <= CHURN_GOSSIP + 1e-9, "{o:?}");
            assert_eq!(o.shards_alive, 3, "{o:?}");
        }
    }

    #[test]
    fn churn_never_double_places_a_stream() {
        // Frame conservation: a stream re-placed while its rejoin races
        // shard-loss detection must be charged exactly once — every cam
        // sees exactly its 600 arrivals, in both runners.
        let scenario = churn_scenario(211);
        for report in [
            run_sharded(&scenario),
            run_sharded_remote(&scenario, RemoteTransport::Tcp).expect("tcp churn"),
        ] {
            for s in &report.streams {
                assert_eq!(s.frames_total, 600, "{}: {s:?}", s.name);
            }
        }
    }

    #[test]
    fn churn_json_reparses() {
        let j = churn_json(7);
        let back = Json::parse(&j.to_string()).expect("churn JSON must reparse");
        assert_eq!(back.get("seed").and_then(Json::as_i64), Some(7));
        let rows = back.get("churn_chaos").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("mode").and_then(Json::as_str), Some("inproc"));
        assert_eq!(rows[1].get("mode").and_then(Json::as_str), Some("tcp"));
    }
}
