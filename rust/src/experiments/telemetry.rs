//! Telemetry sweeps: where the p99 latency budget goes, and what
//! watching costs (see EXPERIMENTS.md §Telemetry for measured numbers).
//!
//! * [`overload_sweep`] — the acceptance sweep: the same 8-stream fleet
//!   run at 0.6×..2× offered load with tracing on. Each point
//!   decomposes the exact p99 frame's latency into its
//!   ingest/queue/detect/deliver stages ([`p99_breakdown`]); because
//!   stage timestamps are consecutive the stages sum to the p99 with no
//!   residue, and the queue stage visibly swallows the budget as load
//!   crosses capacity.
//! * [`attribution`] — joins a gated, mid-run-rescaled run's traces
//!   against its wire log ([`attribute_latency`]): every delivered
//!   frame's latency buckets under the control class that most recently
//!   touched its stream (gate verdict, scripted rescale, or nothing).
//! * [`tracing_overhead`] — tracing is an *observer*: the traced twin
//!   must reproduce the untraced run's virtual-time results exactly
//!   (0% simulated overhead, well inside the 2% budget), and the
//!   min-of-k wall-clock cost of carrying the spans is reported
//!   alongside.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::control::{ControlAction, ControlEvent};
use crate::device::{DetectorModelId, DeviceInstance, DeviceKind};
use crate::experiments::fleet::pool_of;
use crate::fleet::admission::AdmissionPolicy;
use crate::fleet::sim::{run_fleet_with, FleetRunOutput, Scenario};
use crate::fleet::stream::StreamSpec;
use crate::gate::GateConfig;
use crate::telemetry::{attribute_latency, p99_breakdown, STAGES};
use crate::util::json::Json;
use crate::util::table::{f, Table};

/// The sweep's fixed pool: 4 × 2.5-FPS devices (Σμ = 10).
const POOL_RATE: f64 = 10.0;
const SWEEP_STREAMS: usize = 8;

/// Offered-load factors swept by [`overload_sweep`] (offered λ / Σμ).
pub const LOAD_FACTORS: [f64; 4] = [0.6, 1.0, 1.5, 2.0];

fn uniform_streams(n: usize, fps: f64, frames: u64, window: usize) -> Vec<StreamSpec> {
    (0..n)
        .map(|i| StreamSpec::new(&format!("cam{i}"), fps, frames).with_window(window))
        .collect()
}

/// The traced sweep scenario at one load factor. Admission is off so
/// overload shows up as queueing and evictions — exactly the stages the
/// traces are meant to expose — rather than as rejected streams.
pub fn sweep_scenario(seed: u64, load: f64) -> Scenario {
    let fps = load * POOL_RATE / SWEEP_STREAMS as f64;
    Scenario::new(
        pool_of(4, 2.5),
        uniform_streams(SWEEP_STREAMS, fps, 240, 4),
    )
    .with_admission(AdmissionPolicy::admit_all())
    .with_seed(seed)
    .with_telemetry()
}

/// One load point of the stage-budget sweep.
#[derive(Debug, Clone, Copy)]
pub struct StagePoint {
    /// Offered λ / pool Σμ.
    pub load: f64,
    /// Delivered (detected + emitted) frames the p99 rank was drawn from.
    pub delivered: usize,
    /// Nearest-rank p99 capture→deliver latency (seconds).
    pub e2e_p99: f64,
    /// The p99 frame's `[ingest, queue, detect, deliver]` durations.
    pub stages: [f64; 4],
    /// `|Σ stages − p99| / p99` — zero up to float error by construction.
    pub residue: f64,
}

/// Stage-budget sweep: 8 traced streams vs Σμ = 10 at 0.6×..2× load.
pub fn overload_sweep(seed: u64) -> (Table, Vec<StagePoint>) {
    let mut t = Table::new(
        "p99 latency budget by stage (8 traced streams vs Σμ = 10)",
        &[
            "offered/Σμ", "delivered", "p99 (s)", "ingest", "queue", "detect", "deliver",
            "residue %",
        ],
    );
    let mut points = Vec::new();
    for load in LOAD_FACTORS {
        let out = run_fleet_with(&sweep_scenario(seed, load), None);
        let tel = out.telemetry.as_ref().expect("sweep runs traced");
        let b = p99_breakdown(&tel.traces).expect("delivered frames exist");
        let residue = (b.stages.iter().sum::<f64>() - b.e2e_p99).abs() / b.e2e_p99.max(1e-12);
        let p = StagePoint {
            load,
            delivered: b.delivered,
            e2e_p99: b.e2e_p99,
            stages: b.stages,
            residue,
        };
        t.row(vec![
            f(p.load, 1),
            format!("{}", p.delivered),
            f(p.e2e_p99, 3),
            f(p.stages[0], 3),
            f(p.stages[1], 3),
            f(p.stages[2], 3),
            f(p.stages[3], 3),
            f(p.residue * 100.0, 4),
        ]);
        points.push(p);
    }
    (t, points)
}

/// One control class's latency bucket from [`attribution`].
#[derive(Debug, Clone)]
pub struct AttributionRow {
    /// `origin_class` vocabulary: gate / admission / autoscale /
    /// migration / scripted / none.
    pub class: &'static str,
    pub frames: usize,
    pub p50: f64,
    pub p99: f64,
}

/// The attribution scenario: two busy gated streams with a scene cut
/// every 10 frames, and a scripted device attach at t = 3 s. Steady
/// frames always detect (base energy 0.12..0.18 sits above the resume
/// threshold) and are *unlogged*; every 10th frame spikes to a logged
/// scene-cut verdict. So, by construction: cut frames bucket "gate",
/// steady frames captured after the attach bucket "scripted" (pool
/// capacity moved under every stream), and earlier ones "none".
fn attribution_scenario(seed: u64) -> Scenario {
    // pressure_rung 0: overload must not convert steady detects into
    // logged down-rung verdicts, or the non-gate buckets would starve.
    let gate = GateConfig {
        pressure_rung: 0,
        ..GateConfig::for_dynamics(crate::gate::MotionDynamics {
            base: 0.12,
            jitter: 0.06,
            cut_every: 10,
        })
    };
    Scenario::new(pool_of(1, 18.0), uniform_streams(2, 15.0, 120, 4))
        .with_admission(AdmissionPolicy::admit_all())
        .with_seed(seed)
        .with_gate(gate)
        .with_events(vec![ControlEvent {
            at: 3.0,
            action: ControlAction::AttachDevice(DeviceInstance::with_rate(
                DeviceKind::Ncs2,
                DetectorModelId::Yolov3,
                1,
                2.5,
            )),
        }])
        .with_telemetry()
}

/// Latency attribution by control origin on the gated + rescaled run.
pub fn attribution(seed: u64) -> (Table, Vec<AttributionRow>) {
    let out = run_fleet_with(&attribution_scenario(seed), None);
    let tel = out.telemetry.as_ref().expect("attribution runs traced");
    let buckets = attribute_latency(&tel.traces, &out.wire_log());
    let mut t = Table::new(
        "Latency attribution by control origin (gate + scripted rescale)",
        &["class", "frames", "p50 (s)", "p99 (s)"],
    );
    let mut rows = Vec::new();
    for (class, lat) in &buckets {
        let row = AttributionRow {
            class,
            frames: lat.len(),
            p50: lat.p50(),
            p99: lat.p99(),
        };
        t.row(vec![
            row.class.to_string(),
            format!("{}", row.frames),
            f(row.p50, 3),
            f(row.p99, 3),
        ]);
        rows.push(row);
    }
    (t, rows)
}

/// What tracing costs, measured both ways.
#[derive(Debug, Clone, Copy)]
pub struct OverheadOutcome {
    /// Min-of-k wall-clock seconds for the traced run.
    pub traced_wall: f64,
    /// Min-of-k wall-clock seconds for the untraced twin.
    pub untraced_wall: f64,
    /// `traced_wall / untraced_wall − 1` (host-dependent, reported only).
    pub wall_overhead: f64,
    /// Whether the traced run reproduced the untraced run's virtual-time
    /// results exactly (makespan and processed count) — the 0% claim.
    pub virtual_identical: bool,
    /// Frames per run (scales the wall numbers).
    pub frames: u64,
}

/// Observer-overhead measurement: the 1×-load sweep scenario run `k`
/// times traced and untraced, interleaved, min-of-k per arm. Virtual
/// time must be bit-identical (tracing only *watches*); the wall-clock
/// delta is the cost of carrying spans and is reported, not asserted —
/// it depends on the host.
pub fn tracing_overhead(seed: u64) -> (Table, OverheadOutcome) {
    let traced = sweep_scenario(seed, 1.0);
    let mut untraced = traced.clone();
    untraced.telemetry = false;

    let time_run = |s: &Scenario| {
        let start = Instant::now();
        let out = run_fleet_with(s, None);
        (start.elapsed().as_secs_f64(), out)
    };
    let k = 5;
    let (mut t_wall, mut u_wall) = (f64::INFINITY, f64::INFINITY);
    let (mut t_out, mut u_out) = (None, None);
    for _ in 0..k {
        let (dt, out) = time_run(&traced);
        t_wall = t_wall.min(dt);
        t_out = Some(out);
        let (du, out) = time_run(&untraced);
        u_wall = u_wall.min(du);
        u_out = Some(out);
    }
    let (t_out, u_out) = (t_out.expect("k > 0"), u_out.expect("k > 0"));
    let outcome = OverheadOutcome {
        traced_wall: t_wall,
        untraced_wall: u_wall,
        wall_overhead: t_wall / u_wall.max(1e-9) - 1.0,
        virtual_identical: t_out.report.makespan == u_out.report.makespan
            && t_out.report.total_processed() == u_out.report.total_processed(),
        frames: u_out.report.total_frames(),
    };
    let mut t = Table::new(
        "Tracing overhead (min-of-5 wall clock; virtual time must be exact)",
        &["frames", "untraced (ms)", "traced (ms)", "wall Δ %", "virtual time"],
    );
    t.row(vec![
        format!("{}", outcome.frames),
        f(outcome.untraced_wall * 1e3, 3),
        f(outcome.traced_wall * 1e3, 3),
        f(outcome.wall_overhead * 100.0, 1),
        if outcome.virtual_identical { "identical" } else { "DIVERGED" }.to_string(),
    ]);
    (t, outcome)
}

/// Machine-readable bundle (the `eva trace --json` surface): the stage
/// budget, the attribution rows, the overhead outcome, and the peak-load
/// run's full metric snapshot (so the CI artifact carries the schema).
pub fn telemetry_json(seed: u64) -> Json {
    let mut root = BTreeMap::new();
    root.insert("seed".into(), Json::Num(seed as f64));

    let (_, points) = overload_sweep(seed);
    let rows: Vec<Json> = points
        .iter()
        .map(|p| {
            let mut m = BTreeMap::new();
            m.insert("load".into(), Json::Num(p.load));
            m.insert("delivered".into(), Json::Num(p.delivered as f64));
            m.insert("e2e_p99".into(), Json::Num(p.e2e_p99));
            m.insert(
                "stages".into(),
                Json::Obj(
                    STAGES
                        .iter()
                        .zip(p.stages)
                        .map(|(name, secs)| (name.to_string(), Json::Num(secs)))
                        .collect(),
                ),
            );
            m.insert("residue".into(), Json::Num(p.residue));
            Json::Obj(m)
        })
        .collect();
    root.insert("stage_budget".into(), Json::Arr(rows));

    let (_, attr) = attribution(seed);
    let rows: Vec<Json> = attr
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("class".into(), Json::Str(r.class.to_string()));
            m.insert("frames".into(), Json::Num(r.frames as f64));
            m.insert("p50".into(), Json::Num(r.p50));
            m.insert("p99".into(), Json::Num(r.p99));
            Json::Obj(m)
        })
        .collect();
    root.insert("attribution".into(), Json::Arr(rows));

    let (_, o) = tracing_overhead(seed);
    let mut m = BTreeMap::new();
    m.insert("traced_wall".into(), Json::Num(o.traced_wall));
    m.insert("untraced_wall".into(), Json::Num(o.untraced_wall));
    m.insert("wall_overhead".into(), Json::Num(o.wall_overhead));
    m.insert("virtual_identical".into(), Json::Bool(o.virtual_identical));
    m.insert("frames".into(), Json::Num(o.frames as f64));
    root.insert("overhead".into(), Json::Obj(m));

    let peak = run_fleet_with(&sweep_scenario(seed, 2.0), None);
    let tel = peak.telemetry.expect("peak run traced");
    root.insert("registry".into(), tel.registry.to_json());

    Json::Obj(root)
}

/// The traced peak-load run backing `eva trace`'s `--metrics-out` /
/// `--trace-out` files: its registry is the snapshot, its traces the
/// JSONL export.
pub fn traced_run(seed: u64) -> FleetRunOutput {
    run_fleet_with(&sweep_scenario(seed, 2.0), None)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_budget_partitions_p99_without_residue() {
        let (_, points) = overload_sweep(11);
        assert_eq!(points.len(), LOAD_FACTORS.len());
        for p in &points {
            assert!(p.delivered > 0, "{p:?}");
            // The acceptance bound is 1%; consecutive timestamps make it
            // float error in practice.
            assert!(p.residue < 0.01, "{p:?}");
        }
        // Overload swallows the budget in the queue: the 2× point's
        // queue stage dominates its detect stage and dwarfs the 0.6×
        // point's queue wait.
        let (light, heavy) = (&points[0], &points[points.len() - 1]);
        assert!(heavy.e2e_p99 > light.e2e_p99, "{light:?} vs {heavy:?}");
        assert!(heavy.stages[1] > heavy.stages[2], "{heavy:?}");
        assert!(heavy.stages[1] > light.stages[1], "{light:?} vs {heavy:?}");
    }

    #[test]
    fn attribution_covers_gate_script_and_quiet_frames() {
        let (table, rows) = attribution(13);
        let classes: Vec<&str> = rows.iter().map(|r| r.class).collect();
        assert!(classes.contains(&"gate"), "{classes:?}");
        assert!(classes.contains(&"scripted"), "{classes:?}");
        assert!(classes.contains(&"none"), "{classes:?}");
        for r in &rows {
            assert!(r.frames > 0, "{r:?}");
            assert!(r.p99 >= r.p50, "{r:?}");
        }
        assert_eq!(table.rows.len(), rows.len());
    }

    #[test]
    fn tracing_is_a_pure_observer_in_virtual_time() {
        let (_, o) = tracing_overhead(17);
        assert!(o.virtual_identical, "{o:?}");
        assert!(o.frames > 0, "{o:?}");
        assert!(o.untraced_wall > 0.0 && o.traced_wall > 0.0, "{o:?}");
    }

    #[test]
    fn json_bundle_reparses_with_all_sections() {
        let j = telemetry_json(5);
        let back = Json::parse(&j.to_string()).expect("telemetry JSON must reparse");
        assert_eq!(back.get("seed").and_then(Json::as_i64), Some(5));
        assert_eq!(
            back.get("stage_budget").unwrap().as_arr().unwrap().len(),
            LOAD_FACTORS.len()
        );
        assert!(!back.get("attribution").unwrap().as_arr().unwrap().is_empty());
        let overhead = back.get("overhead").expect("overhead section");
        assert_eq!(
            overhead.get("virtual_identical").and_then(Json::as_bool),
            Some(true)
        );
        // The registry snapshot rides along and round-trips through the
        // snapshot decoder.
        let reg = back.get("registry").expect("registry section");
        let decoded =
            crate::telemetry::Registry::from_json(reg).expect("snapshot must decode");
        assert!(decoded.counter_family_total("eva_frames_total") > 0);
    }
}
