//! Per-frame span tracing: stage timestamps, JSONL export, and the
//! join against the replayable control-plane event log.
//!
//! A [`FrameTrace`] carries consecutive timestamps through one frame's
//! life — capture → admit/gate → queue exit (detect start) → detect end
//! → deliver — so stage durations *partition* the capture→emit latency
//! exactly: `ingest + queue + detect + deliver == e2e` by construction,
//! with no residue for a p99 budget to hide in. Frames that never reach
//! a detector (stride-dropped, gate-skipped, evicted, rejected, drained
//! at shutdown) still get a trace with the drop reason, so the
//! accounting closes over every captured frame.
//!
//! [`attribute_latency`] joins delivered traces against a run's
//! [`EventLog`]: each frame buckets under the control class that most
//! recently touched its stream at capture time (an exact per-frame gate
//! verdict wins outright), lowering "where did the p99 go" to "which
//! controller put it there".

use std::collections::{BTreeMap, BTreeSet};

use crate::control::{ControlAction, ControlOrigin, EventLog, WireEvent, WirePayload};
use crate::telemetry::registry::{MetricKey, Registry};
use crate::util::json::Json;
use crate::util::stats::Percentiles;

/// Stage names, in frame-life order (shared by metric labels, tables
/// and the JSONL export so they cannot drift apart).
pub const STAGES: [&str; 4] = ["ingest", "queue", "detect", "deliver"];

/// How one captured frame left the pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceOutcome {
    /// Detected and emitted by the synchronizer.
    Delivered,
    /// Stream was rejected by admission; the frame never entered.
    DroppedRejected,
    /// Dropped by the admission stride before the window.
    DroppedStride,
    /// Skipped by a motion-gate verdict.
    DroppedGate,
    /// Evicted from a full window by a newer arrival.
    DroppedEvicted,
    /// Still queued when the run drained.
    DroppedDrained,
}

impl TraceOutcome {
    pub fn label(&self) -> &'static str {
        match self {
            TraceOutcome::Delivered => "delivered",
            TraceOutcome::DroppedRejected => "rejected",
            TraceOutcome::DroppedStride => "stride",
            TraceOutcome::DroppedGate => "gate",
            TraceOutcome::DroppedEvicted => "evicted",
            TraceOutcome::DroppedDrained => "drained",
        }
    }
}

/// One frame's span record. Times are engine time — virtual seconds in
/// [`crate::fleet::sim`], wall-clock seconds since run start in
/// [`crate::fleet::serve`].
#[derive(Debug, Clone, PartialEq)]
pub struct FrameTrace {
    pub stream: usize,
    pub frame: u64,
    /// Capture timestamp (`frame / fps` in virtual time).
    pub capture: f64,
    /// Admission/gate verdict applied; equals `capture` in virtual time
    /// (the gate decides at arrival), trails it in wall clock.
    pub admit: f64,
    /// Queue exit = detector start (`None` if never dispatched).
    pub detect_start: Option<f64>,
    pub detect_end: Option<f64>,
    /// Synchronizer emit time (set for every emitted record, including
    /// stale-box emissions of dropped frames).
    pub deliver: Option<f64>,
    pub outcome: TraceOutcome,
    /// Model-ladder rung the frame was served at.
    pub rung: Option<usize>,
    /// Device (virtual-time pool index / wall-clock worker index).
    pub device: Option<usize>,
}

impl FrameTrace {
    /// Capture→deliver latency, when the frame was emitted.
    pub fn e2e(&self) -> Option<f64> {
        self.deliver.map(|d| (d - self.capture).max(0.0))
    }

    /// Stage durations `[ingest, queue, detect, deliver]` for a
    /// delivered, detected frame. They sum to [`FrameTrace::e2e`]
    /// exactly (consecutive timestamps; nothing is measured twice).
    pub fn stage_seconds(&self) -> Option<[f64; 4]> {
        let (ds, de, dl) = (self.detect_start?, self.detect_end?, self.deliver?);
        Some([
            (self.admit - self.capture).max(0.0),
            (ds - self.admit).max(0.0),
            (de - ds).max(0.0),
            (dl - de).max(0.0),
        ])
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("stream".to_string(), Json::Num(self.stream as f64));
        o.insert("frame".to_string(), Json::Num(self.frame as f64));
        o.insert("capture".to_string(), Json::Num(self.capture));
        o.insert("admit".to_string(), Json::Num(self.admit));
        let opt = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
        o.insert("detect_start".to_string(), opt(self.detect_start));
        o.insert("detect_end".to_string(), opt(self.detect_end));
        o.insert("deliver".to_string(), opt(self.deliver));
        o.insert(
            "outcome".to_string(),
            Json::Str(self.outcome.label().to_string()),
        );
        o.insert(
            "rung".to_string(),
            self.rung.map(|r| Json::Num(r as f64)).unwrap_or(Json::Null),
        );
        o.insert(
            "device".to_string(),
            self.device.map(|d| Json::Num(d as f64)).unwrap_or(Json::Null),
        );
        Json::Obj(o)
    }
}

/// Render traces as JSONL (one compact object per line), the
/// `--trace-out` file format.
pub fn traces_jsonl(traces: &[FrameTrace]) -> String {
    let mut out = String::new();
    for t in traces {
        out.push_str(&t.to_json().to_string());
        out.push('\n');
    }
    out
}

/// Everything a traced run hands back: the metrics registry and the
/// per-frame spans.
#[derive(Debug, Clone, Default)]
pub struct RunTelemetry {
    pub registry: Registry,
    pub traces: Vec<FrameTrace>,
}

impl RunTelemetry {
    pub fn traces_jsonl(&self) -> String {
        traces_jsonl(&self.traces)
    }
}

/// Standard metric names for a traced fleet run. [`record_traces`] is
/// the single place that lowers traces into the registry, so the metric
/// schema cannot drift between the two engines.
pub fn record_traces(reg: &mut Registry, traces: &[FrameTrace]) {
    for t in traces {
        reg.inc(
            MetricKey::with_labels("eva_frames_total", &[("outcome", t.outcome.label())]),
            1,
        );
        if let Some(e2e) = t.e2e() {
            if t.outcome == TraceOutcome::Delivered {
                reg.observe(MetricKey::new("eva_e2e_seconds"), e2e);
            }
        }
        if let Some(stages) = t.stage_seconds() {
            for (name, secs) in STAGES.iter().zip(stages) {
                reg.observe(
                    MetricKey::with_labels("eva_stage_seconds", &[("stage", name)]),
                    secs,
                );
            }
        }
    }
}

/// Per-stage decomposition of the exact p99 frame.
#[derive(Debug, Clone, PartialEq)]
pub struct StageBreakdown {
    /// The nearest-rank p99 capture→deliver latency.
    pub e2e_p99: f64,
    /// That frame's `[ingest, queue, detect, deliver]` durations — they
    /// sum to `e2e_p99` exactly.
    pub stages: [f64; 4],
    /// Delivered frames the rank was drawn from.
    pub delivered: usize,
}

/// Decompose the p99 latency budget across stages: find the delivered
/// frame holding the nearest-rank p99 end-to-end latency and return its
/// exact stage partition (not per-stage p99s, which need not sum to
/// anything). `None` without delivered, detected frames.
pub fn p99_breakdown(traces: &[FrameTrace]) -> Option<StageBreakdown> {
    let delivered: Vec<&FrameTrace> = traces
        .iter()
        .filter(|t| t.outcome == TraceOutcome::Delivered && t.stage_seconds().is_some())
        .collect();
    if delivered.is_empty() {
        return None;
    }
    let mut lat = Percentiles::new();
    for t in &delivered {
        lat.push(t.e2e().unwrap_or(0.0));
    }
    let p99 = lat.p99();
    // The nearest-rank quantile is an actual sample: recover its frame
    // (first match; ties share the same e2e by definition).
    let frame = delivered
        .iter()
        .find(|t| t.e2e() == Some(p99))
        .expect("p99 is a sample");
    Some(StageBreakdown {
        e2e_p99: p99,
        stages: frame.stage_seconds().expect("delivered frame has stages"),
        delivered: delivered.len(),
    })
}

/// Coarse attribution class of one wire event (the vocabulary of
/// [`attribute_latency`] buckets).
pub fn origin_class(ev: &WireEvent) -> &'static str {
    match &ev.payload {
        WirePayload::Gate { .. } => "gate",
        WirePayload::Decision { .. } => "admission",
        WirePayload::Action(_) => match ev.origin {
            ControlOrigin::Controller => "autoscale",
            ControlOrigin::Placement => "migration",
            ControlOrigin::Gate => "gate",
            ControlOrigin::Admission => "admission",
            ControlOrigin::Scripted => "scripted",
        },
    }
}

/// Whether `ev` touches stream `sid` (stream-scoped payloads) or every
/// stream (device-scoped actions: pool capacity moved under everyone).
fn touches_stream(ev: &WireEvent, sid: usize) -> bool {
    match &ev.payload {
        WirePayload::Gate { stream, .. } | WirePayload::Decision { stream, .. } => *stream == sid,
        WirePayload::Action(a) => match a {
            ControlAction::AttachStream(_) => false,
            ControlAction::DetachStream(id) => *id == sid,
            ControlAction::SwapModel { stream, .. } => *stream == sid,
            ControlAction::AttachDevice(_) | ControlAction::DetachDevice(_) => true,
        },
    }
}

/// Join delivered traces against the run's wire log: bucket each
/// frame's end-to-end latency by the class of the most recent event
/// touching its stream at or before capture time. An exact per-frame
/// gate verdict wins outright; frames no event ever touched bucket
/// under `"none"`. Returns `class → latency samples`, deterministic
/// (BTreeMap, log order).
pub fn attribute_latency(
    traces: &[FrameTrace],
    log: &EventLog,
) -> BTreeMap<&'static str, Percentiles> {
    // Exact (stream, frame) gate verdicts.
    let gated: BTreeSet<(usize, u64)> = log
        .events
        .iter()
        .filter_map(|e| match &e.payload {
            WirePayload::Gate { stream, frame, .. } => Some((*stream, *frame)),
            _ => None,
        })
        .collect();
    let mut out: BTreeMap<&'static str, Percentiles> = BTreeMap::new();
    for t in traces {
        let Some(e2e) = t.e2e() else { continue };
        let class = if gated.contains(&(t.stream, t.frame)) {
            "gate"
        } else {
            log.events
                .iter()
                .rev()
                .find(|e| e.at <= t.capture + 1e-12 && touches_stream(e, t.stream))
                .map(origin_class)
                .unwrap_or("none")
        };
        out.entry(class).or_default().push(e2e);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::admission::Decision;
    use crate::gate::GateVerdict;

    fn delivered(stream: usize, frame: u64, capture: f64) -> FrameTrace {
        FrameTrace {
            stream,
            frame,
            capture,
            admit: capture,
            detect_start: Some(capture + 0.2),
            detect_end: Some(capture + 0.5),
            deliver: Some(capture + 0.6),
            outcome: TraceOutcome::Delivered,
            rung: Some(0),
            device: Some(0),
        }
    }

    #[test]
    fn stage_durations_partition_e2e_exactly() {
        let t = delivered(0, 3, 1.5);
        let stages = t.stage_seconds().expect("stages");
        let e2e = t.e2e().expect("e2e");
        assert!((stages.iter().sum::<f64>() - e2e).abs() < 1e-12);
        assert_eq!(stages[0], 0.0);
        assert!((stages[1] - 0.2).abs() < 1e-12);
        assert!((stages[2] - 0.3).abs() < 1e-12);
        assert!((stages[3] - 0.1).abs() < 1e-12);
    }

    #[test]
    fn dropped_frames_have_no_stage_partition_but_keep_their_reason() {
        let t = FrameTrace {
            stream: 1,
            frame: 9,
            capture: 2.0,
            admit: 2.0,
            detect_start: None,
            detect_end: None,
            deliver: Some(2.4),
            outcome: TraceOutcome::DroppedGate,
            rung: None,
            device: None,
        };
        assert_eq!(t.stage_seconds(), None);
        assert_eq!(t.e2e(), Some(0.4));
        assert_eq!(t.outcome.label(), "gate");
    }

    #[test]
    fn p99_breakdown_sums_to_the_p99_frame() {
        let traces: Vec<FrameTrace> = (0..100)
            .map(|i| {
                let mut t = delivered(0, i, i as f64 * 0.1);
                // Frame 99 is the slowpoke: a long queue wait.
                if i == 99 {
                    t.detect_start = Some(t.capture + 3.0);
                    t.detect_end = Some(t.capture + 3.3);
                    t.deliver = Some(t.capture + 3.4);
                }
                t
            })
            .collect();
        let b = p99_breakdown(&traces).expect("breakdown");
        assert_eq!(b.delivered, 100);
        assert!((b.stages.iter().sum::<f64>() - b.e2e_p99).abs() < 1e-12);
        assert!((b.e2e_p99 - 3.4).abs() < 1e-12);
        assert!(b.stages[1] > b.stages[2], "queue dominates: {:?}", b.stages);
    }

    #[test]
    fn jsonl_is_one_parseable_object_per_line() {
        let traces = vec![delivered(0, 0, 0.0), delivered(1, 1, 0.5)];
        let jsonl = traces_jsonl(&traces);
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            let v = Json::parse(line).expect("parse");
            assert!(v.get("stream").is_some());
            assert_eq!(v.get("outcome").and_then(Json::as_str), Some("delivered"));
        }
    }

    #[test]
    fn record_traces_populates_the_standard_schema() {
        let mut reg = Registry::new();
        let mut traces = vec![delivered(0, 0, 0.0)];
        traces.push(FrameTrace {
            outcome: TraceOutcome::DroppedStride,
            detect_start: None,
            detect_end: None,
            deliver: None,
            ..delivered(0, 1, 0.1)
        });
        record_traces(&mut reg, &traces);
        assert_eq!(
            reg.counter(&MetricKey::with_labels("eva_frames_total", &[("outcome", "delivered")])),
            1
        );
        assert_eq!(
            reg.counter(&MetricKey::with_labels("eva_frames_total", &[("outcome", "stride")])),
            1
        );
        for stage in STAGES {
            let h = reg
                .histogram(&MetricKey::with_labels("eva_stage_seconds", &[("stage", stage)]))
                .expect(stage);
            assert_eq!(h.count(), 1, "{stage}");
        }
    }

    #[test]
    fn attribution_joins_traces_with_the_event_log() {
        let mut log = EventLog::new();
        // Stream 0 frame 5 gets an exact gate verdict; stream 1 is
        // admitted (decision at t=0); a device attaches at t=0.35 with
        // Controller origin (autoscale class) touching every stream.
        log.push(WireEvent::gate(0.5, 0, 5, GateVerdict::Skip));
        log.push(WireEvent::decision(0.0, 1, Decision::Admit { share: 5.0 }));
        log.push(WireEvent::action(
            0.35,
            ControlOrigin::Controller,
            ControlAction::AttachDevice(crate::device::DeviceInstance::new(
                crate::device::DeviceKind::FastCpu,
                crate::device::DetectorModelId::Yolov3,
                7,
            )),
        ));
        let traces = vec![
            delivered(0, 5, 0.5), // exact gate hit
            delivered(1, 0, 0.1), // after its admission decision, before the attach
            delivered(1, 9, 0.9), // after the attach → autoscale
            delivered(2, 0, 0.0), // untouched stream at t=0... attach at 0.35 is later
        ];
        let buckets = attribute_latency(&traces, &log);
        assert_eq!(buckets.get("gate").map(|p| p.len()), Some(1));
        assert_eq!(buckets.get("admission").map(|p| p.len()), Some(1));
        assert_eq!(buckets.get("autoscale").map(|p| p.len()), Some(1));
        assert_eq!(buckets.get("none").map(|p| p.len()), Some(1));
    }

    #[test]
    fn origin_class_covers_the_vocabulary() {
        let gate = WireEvent::gate(0.0, 0, 0, GateVerdict::Skip);
        assert_eq!(origin_class(&gate), "gate");
        let dec = WireEvent::decision(0.0, 0, Decision::Admit { share: 1.0 });
        assert_eq!(origin_class(&dec), "admission");
        let mig = WireEvent::action(0.0, ControlOrigin::Placement, ControlAction::DetachStream(0));
        assert_eq!(origin_class(&mig), "migration");
        let scale = WireEvent::action(0.0, ControlOrigin::Controller, ControlAction::DetachDevice(0));
        assert_eq!(origin_class(&scale), "autoscale");
    }
}
