//! End-to-end telemetry: metrics registry + per-frame span tracing.
//!
//! The paper's diagnosis method is rate/latency accounting — finding the
//! mismatch among incoming stream rate, detection processing rate and
//! output rate (§ III). The rest of the crate could only report that
//! mismatch as end-of-run aggregates; this layer makes the *inside* of a
//! frame's life observable:
//!
//! * [`registry`] — a zero-dependency metrics registry: labelled
//!   counters, gauges and fixed-bucket log-scale latency [`Histogram`]s
//!   with exact p50/p99 queries up to a bounded reservoir
//!   ([`registry::RESERVOIR_CAP`]; past it, deterministic stride
//!   thinning keeps memory flat and quantiles approximate), a
//!   Prometheus-style text exposition and a JSON snapshot over
//!   [`crate::util::json`]. Registries merge, so per-shard snapshots
//!   shipped over the wire fold into one fleet view.
//! * [`trace`] — per-frame span tracing: every frame gets a
//!   [`FrameTrace`] of stage timestamps (capture → admit/gate → queue →
//!   detect → deliver), recorded by both the virtual-time
//!   ([`crate::fleet::sim`]) and wall-clock ([`crate::fleet::serve`])
//!   engines. Consecutive timestamps partition the capture→emit latency
//!   *exactly*, so a p99 budget decomposes into stage contributions
//!   without residue. Traces export as JSONL and join against the
//!   replayable [`crate::control::EventLog`]
//!   ([`trace::attribute_latency`]) so latency buckets by the control
//!   class that touched the frame: gate verdict, admission decision,
//!   autoscale action, migration.
//!
//! Everything here is engine-agnostic plain data; the engines opt in
//! (`Scenario::with_telemetry`, `serve_fleet_traced`) and pay nothing
//! when they don't.

pub mod registry;
pub mod trace;

pub use registry::{Histogram, MetricKey, Registry, RESERVOIR_CAP, SNAPSHOT_VERSION};
pub use trace::{
    attribute_latency, origin_class, p99_breakdown, record_traces, FrameTrace, RunTelemetry,
    StageBreakdown, TraceOutcome, STAGES,
};
