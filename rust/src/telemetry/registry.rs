//! Zero-dependency metrics registry: counters, gauges, log-scale
//! latency histograms, text exposition and a JSON snapshot.
//!
//! Design constraints, in order:
//!
//! * **Deterministic.** Metrics are keyed by [`MetricKey`] in
//!   `BTreeMap`s, labels are kept sorted, and the exposition walks keys
//!   in order — two registries fed the same observations render
//!   byte-identical text and JSON. That is what lets the transport
//!   parity tests compare snapshots scraped over tcp/uds against the
//!   in-process run *exactly*.
//! * **Exact quantiles while small, bounded memory always.** A
//!   [`Histogram`] is a fixed set of log-scale bucket counts (cheap to
//!   merge and ship) *plus* a sample reservoir
//!   ([`crate::util::stats::Percentiles`]) that is exact up to
//!   [`RESERVOIR_CAP`] observations — "p99" means the real 99th sample
//!   — and past the cap thins deterministically (keep-every-nth with a
//!   doubling stride), so a long-running shard cannot grow its
//!   registry without bound.
//! * **Mergeable.** [`Registry::merge`] folds another registry in
//!   (counters add, gauges overwrite, histograms merge bucket-wise), so
//!   per-shard snapshots shipped over the wire aggregate into one fleet
//!   view at the coordinator.

use std::collections::BTreeMap;

use crate::control::wire::WireError;
use crate::util::json::Json;
use crate::util::stats::Percentiles;

/// Snapshot format version stamped on every encoded registry.
pub const SNAPSHOT_VERSION: i64 = 1;

/// A metric identity: family name plus sorted `(label, value)` pairs.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct MetricKey {
    pub name: String,
    pub labels: Vec<(String, String)>,
}

impl MetricKey {
    pub fn new(name: &str) -> MetricKey {
        MetricKey {
            name: name.to_string(),
            labels: Vec::new(),
        }
    }

    /// Key with labels (sorted by label name, so insertion order cannot
    /// split one logical series into two).
    pub fn with_labels(name: &str, labels: &[(&str, &str)]) -> MetricKey {
        let mut labels: Vec<(String, String)> = labels
            .iter()
            .map(|&(k, v)| (k.to_string(), v.to_string()))
            .collect();
        labels.sort();
        MetricKey {
            name: name.to_string(),
            labels,
        }
    }

    /// `name{k="v",...}` (or bare `name` without labels).
    fn render(&self) -> String {
        if self.labels.is_empty() {
            return self.name.clone();
        }
        let inner: Vec<String> = self
            .labels
            .iter()
            .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
            .collect();
        format!("{}{{{}}}", self.name, inner.join(","))
    }

    fn labels_json(&self) -> Json {
        Json::Obj(
            self.labels
                .iter()
                .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                .collect(),
        )
    }
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Format an f64 the way [`crate::util::json`] does (integral values
/// without a fraction, shortest round-trip otherwise), so the text
/// exposition and the JSON snapshot agree on every number.
fn fmt_f64(n: f64) -> String {
    if !n.is_finite() {
        "null".to_string()
    } else if n.fract() == 0.0 && n.abs() < 9e15 {
        format!("{}", n as i64)
    } else {
        format!("{n}")
    }
}

/// Retained-sample ceiling for a [`Histogram`]'s quantile reservoir.
/// Up to this many observations the reservoir is exact; past it, a
/// deterministic keep-every-other compaction halves the retained set
/// and doubles the keep stride, bounding memory at the cap while the
/// bucket counts and sum stay exact forever.
pub const RESERVOIR_CAP: usize = 4096;

/// Fixed-bucket log-scale histogram with an embedded quantile
/// reservoir. Buckets are upper bounds (`value <= bound` counts toward
/// the bucket); values above the last bound land in a saturating
/// overflow bucket.
///
/// The reservoir holds every sample up to [`RESERVOIR_CAP`], so small
/// runs keep the original exact-quantile contract ("p99" is the real
/// 99th sample). Past the cap it keeps every `stride`-th observation
/// (stride doubling on each compaction) — a deterministic, seedless
/// thinning, so two histograms fed the same observation sequence stay
/// byte-identical, which the cross-mode telemetry parity tests rely
/// on. Quantiles over the thinned reservoir are approximations whose
/// error the tests bound.
#[derive(Debug, Clone)]
pub struct Histogram {
    bounds: Vec<f64>,
    /// One count per bound, plus the trailing overflow bucket.
    counts: Vec<u64>,
    sum: f64,
    exact: Percentiles,
    /// Total observations (retained or not).
    observed: u64,
    /// Current keep-every-`stride` retention (1 = keeping everything).
    stride: u64,
}

impl Histogram {
    /// The default latency scale: 18 log-2 buckets from 1 ms to ~131 s.
    /// Virtual-time service times and wall-clock stage latencies both
    /// live comfortably inside this range; anything slower saturates
    /// into the overflow bucket.
    pub fn latency() -> Histogram {
        Histogram::with_bounds((0..18).map(|i| 1e-3 * f64::powi(2.0, i)).collect())
    }

    /// Custom bucket upper bounds (must be non-empty and ascending).
    pub fn with_bounds(bounds: Vec<f64>) -> Histogram {
        assert!(!bounds.is_empty(), "histogram needs at least one bucket");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must ascend"
        );
        let n = bounds.len();
        Histogram {
            bounds,
            counts: vec![0; n + 1],
            sum: 0.0,
            exact: Percentiles::new(),
            observed: 0,
            stride: 1,
        }
    }

    pub fn observe(&mut self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] = self.counts[idx].saturating_add(1);
        self.sum += v;
        // Keep every stride-th observation (0-based), compacting when
        // the reservoir outgrows the cap.
        if (self.observed % self.stride) == 0 {
            self.exact.push(v);
            if self.exact.len() > RESERVOIR_CAP {
                self.compact();
            }
        }
        self.observed = self.observed.saturating_add(1);
    }

    /// Halve the reservoir: drop every other retained sample (in push
    /// order) and double the stride. Deterministic — no RNG, no clock.
    fn compact(&mut self) {
        let mut thinned = Percentiles::new();
        for &s in self.exact.samples().iter().step_by(2) {
            thinned.push(s);
        }
        self.exact = thinned;
        self.stride = self.stride.saturating_mul(2);
    }

    pub fn count(&self) -> u64 {
        self.observed
    }

    /// Samples currently retained by the quantile reservoir (≤
    /// [`RESERVOIR_CAP`]; equals [`Histogram::count`] until the first
    /// compaction).
    pub fn retained(&self) -> usize {
        self.exact.len()
    }

    /// Current keep-every-nth retention stride (1 until the reservoir
    /// first overflows the cap).
    pub fn stride(&self) -> u64 {
        self.stride
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Per-bucket counts, overflow bucket last (not cumulative).
    pub fn bucket_counts(&self) -> &[u64] {
        &self.counts
    }

    /// Exact percentile over the observed samples (0 when empty).
    pub fn pct(&self, p: f64) -> f64 {
        self.exact.pct(p)
    }

    pub fn p50(&self) -> f64 {
        self.pct(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.pct(99.0)
    }

    /// Fold another histogram in. Panics on mismatched bucket bounds —
    /// merging across scales silently would corrupt both. Bucket counts
    /// and sum merge exactly; the reservoirs concatenate (ours first,
    /// then the other's, both in push order) and re-compact until the
    /// result fits the cap — deterministic, and exact as long as the
    /// combined reservoirs were (both strides 1, total ≤ cap).
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.bounds, other.bounds, "histogram bounds differ");
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c = c.saturating_add(*o);
        }
        self.sum += other.sum;
        self.observed = self.observed.saturating_add(other.observed);
        self.stride = self.stride.max(other.stride);
        for &s in other.exact.samples() {
            self.exact.push(s);
        }
        while self.exact.len() > RESERVOIR_CAP {
            self.compact();
        }
    }

    fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert(
            "bounds".to_string(),
            Json::Arr(self.bounds.iter().map(|&b| Json::Num(b)).collect()),
        );
        o.insert(
            "counts".to_string(),
            Json::Arr(self.counts.iter().map(|&c| Json::Num(c as f64)).collect()),
        );
        o.insert("sum".to_string(), Json::Num(self.sum));
        o.insert(
            "samples".to_string(),
            Json::Arr(self.exact.samples().iter().map(|&s| Json::Num(s)).collect()),
        );
        o.insert("observed".to_string(), Json::Num(self.observed as f64));
        o.insert("stride".to_string(), Json::Num(self.stride as f64));
        Json::Obj(o)
    }

    fn from_json(v: &Json) -> Result<Histogram, WireError> {
        let bounds = num_array(v, "bounds")?;
        let counts = num_array(v, "counts")?;
        let samples = num_array(v, "samples")?;
        if bounds.is_empty() || counts.len() != bounds.len() + 1 {
            return Err(WireError::new("histogram bounds/counts shape mismatch"));
        }
        if samples.len() > RESERVOIR_CAP {
            return Err(WireError::new("histogram reservoir exceeds the cap"));
        }
        let mut h = Histogram::with_bounds(bounds);
        h.counts = counts.iter().map(|&c| c as u64).collect();
        h.sum = v
            .get("sum")
            .and_then(Json::as_f64)
            .ok_or_else(|| WireError::new("missing or mistyped field \"sum\""))?;
        // Restore the reservoir verbatim — re-observing would re-thin.
        // `observed`/`stride` default for pre-compaction snapshots
        // (every sample retained, stride 1).
        let observed = match v.get("observed") {
            Some(x) => x
                .as_f64()
                .ok_or_else(|| WireError::new("mistyped field \"observed\""))?
                as u64,
            None => samples.len() as u64,
        };
        let stride = match v.get("stride") {
            Some(x) => {
                let s = x
                    .as_f64()
                    .ok_or_else(|| WireError::new("mistyped field \"stride\""))?;
                if s < 1.0 {
                    return Err(WireError::new("histogram stride must be >= 1"));
                }
                s as u64
            }
            None => 1,
        };
        for s in samples {
            h.exact.push(s);
        }
        h.observed = observed;
        h.stride = stride;
        Ok(h)
    }
}

impl PartialEq for Histogram {
    fn eq(&self, other: &Histogram) -> bool {
        self.bounds == other.bounds
            && self.counts == other.counts
            && self.sum == other.sum
            && self.observed == other.observed
            && self.stride == other.stride
            && self.exact.samples() == other.exact.samples()
    }
}

fn num_array(v: &Json, key: &str) -> Result<Vec<f64>, WireError> {
    let raw = v
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| WireError::new(format!("missing or mistyped field {key:?}")))?;
    let mut out = Vec::with_capacity(raw.len());
    for x in raw {
        out.push(
            x.as_f64()
                .ok_or_else(|| WireError::new(format!("{key} entries must be numbers")))?,
        );
    }
    Ok(out)
}

/// The registry: every metric of a run, deterministically ordered.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Registry {
    counters: BTreeMap<MetricKey, u64>,
    gauges: BTreeMap<MetricKey, f64>,
    histograms: BTreeMap<MetricKey, Histogram>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.histograms.is_empty()
    }

    /// Add to a counter (creating it at zero).
    pub fn inc(&mut self, key: MetricKey, by: u64) {
        let c = self.counters.entry(key).or_insert(0);
        *c = c.saturating_add(by);
    }

    pub fn set_gauge(&mut self, key: MetricKey, v: f64) {
        self.gauges.insert(key, v);
    }

    /// Observe into a histogram, creating it on the default latency
    /// scale ([`Histogram::latency`]) if absent.
    pub fn observe(&mut self, key: MetricKey, v: f64) {
        self.histograms
            .entry(key)
            .or_insert_with(Histogram::latency)
            .observe(v);
    }

    pub fn counter(&self, key: &MetricKey) -> u64 {
        self.counters.get(key).copied().unwrap_or(0)
    }

    pub fn gauge(&self, key: &MetricKey) -> Option<f64> {
        self.gauges.get(key).copied()
    }

    pub fn histogram(&self, key: &MetricKey) -> Option<&Histogram> {
        self.histograms.get(key)
    }

    /// Sum of every counter in family `name` across its label sets.
    pub fn counter_family_total(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .filter(|(k, _)| k.name == name)
            .map(|(_, &v)| v)
            .sum()
    }

    /// Fold `other` in: counters add, gauges overwrite (last writer
    /// wins), histograms merge bucket-wise.
    pub fn merge(&mut self, other: &Registry) {
        for (k, &v) in &other.counters {
            self.inc(k.clone(), v);
        }
        for (k, &v) in &other.gauges {
            self.set_gauge(k.clone(), v);
        }
        for (k, h) in &other.histograms {
            match self.histograms.get_mut(k) {
                Some(mine) => mine.merge(h),
                None => {
                    self.histograms.insert(k.clone(), h.clone());
                }
            }
        }
    }

    /// Prometheus-style text exposition: `# TYPE` headers, one sample
    /// per line, histograms as cumulative `_bucket{le=...}` series with
    /// `_sum` / `_count`. Deterministic: keys render in `BTreeMap`
    /// order.
    pub fn text_exposition(&self) -> String {
        let mut out = String::new();
        let mut last_family = String::new();
        for (k, &v) in &self.counters {
            if k.name != last_family {
                out.push_str(&format!("# TYPE {} counter\n", k.name));
                last_family = k.name.clone();
            }
            out.push_str(&format!("{} {v}\n", k.render()));
        }
        last_family.clear();
        for (k, &v) in &self.gauges {
            if k.name != last_family {
                out.push_str(&format!("# TYPE {} gauge\n", k.name));
                last_family = k.name.clone();
            }
            out.push_str(&format!("{} {}\n", k.render(), fmt_f64(v)));
        }
        last_family.clear();
        for (k, h) in &self.histograms {
            if k.name != last_family {
                out.push_str(&format!("# TYPE {} histogram\n", k.name));
                last_family = k.name.clone();
            }
            let mut cum = 0u64;
            for (i, &c) in h.counts.iter().enumerate() {
                cum = cum.saturating_add(c);
                let le = if i < h.bounds.len() {
                    fmt_f64(h.bounds[i])
                } else {
                    "+Inf".to_string()
                };
                let mut bk = k.clone();
                bk.name = format!("{}_bucket", k.name);
                bk.labels.push(("le".to_string(), le));
                bk.labels.sort();
                out.push_str(&format!("{} {cum}\n", bk.render()));
            }
            let mut sk = k.clone();
            sk.name = format!("{}_sum", k.name);
            out.push_str(&format!("{} {}\n", sk.render(), fmt_f64(h.sum)));
            let mut ck = k.clone();
            ck.name = format!("{}_count", k.name);
            out.push_str(&format!("{} {}\n", ck.render(), h.count()));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        fn series(key: &MetricKey, value: Json) -> Json {
            let mut o = BTreeMap::new();
            o.insert("name".to_string(), Json::Str(key.name.clone()));
            o.insert("labels".to_string(), key.labels_json());
            o.insert("value".to_string(), value);
            Json::Obj(o)
        }
        let mut o = BTreeMap::new();
        o.insert("format".to_string(), Json::Num(SNAPSHOT_VERSION as f64));
        o.insert(
            "counters".to_string(),
            Json::Arr(
                self.counters
                    .iter()
                    .map(|(k, &v)| series(k, Json::Num(v as f64)))
                    .collect(),
            ),
        );
        o.insert(
            "gauges".to_string(),
            Json::Arr(
                self.gauges
                    .iter()
                    .map(|(k, &v)| series(k, Json::Num(v)))
                    .collect(),
            ),
        );
        o.insert(
            "histograms".to_string(),
            Json::Arr(
                self.histograms
                    .iter()
                    .map(|(k, h)| series(k, h.to_json()))
                    .collect(),
            ),
        );
        Json::Obj(o)
    }

    pub fn from_json(v: &Json) -> Result<Registry, WireError> {
        let format = v
            .get("format")
            .and_then(Json::as_i64)
            .ok_or_else(|| WireError::new("missing snapshot format"))?;
        if format != SNAPSHOT_VERSION {
            return Err(WireError::new(format!(
                "unsupported snapshot format {format} (expected {SNAPSHOT_VERSION})"
            )));
        }
        fn key_of(s: &Json) -> Result<MetricKey, WireError> {
            let name = s
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| WireError::new("series missing name"))?
                .to_string();
            let raw = s
                .get("labels")
                .and_then(Json::as_obj)
                .ok_or_else(|| WireError::new("series missing labels"))?;
            let mut labels = Vec::with_capacity(raw.len());
            for (k, v) in raw {
                labels.push((
                    k.clone(),
                    v.as_str()
                        .ok_or_else(|| WireError::new("label values must be strings"))?
                        .to_string(),
                ));
            }
            labels.sort();
            Ok(MetricKey { name, labels })
        }
        fn arr<'a>(v: &'a Json, key: &str) -> Result<&'a [Json], WireError> {
            v.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| WireError::new(format!("missing or mistyped field {key:?}")))
        }
        let mut reg = Registry::new();
        for s in arr(v, "counters")? {
            let value = s
                .get("value")
                .and_then(Json::as_f64)
                .ok_or_else(|| WireError::new("counter missing value"))?;
            reg.counters.insert(key_of(s)?, value as u64);
        }
        for s in arr(v, "gauges")? {
            let value = s
                .get("value")
                .and_then(Json::as_f64)
                .ok_or_else(|| WireError::new("gauge missing value"))?;
            reg.gauges.insert(key_of(s)?, value);
        }
        for s in arr(v, "histograms")? {
            let value = s
                .get("value")
                .ok_or_else(|| WireError::new("histogram missing value"))?;
            reg.histograms.insert(key_of(s)?, Histogram::from_json(value)?);
        }
        Ok(reg)
    }

    /// Serialise the snapshot to a compact JSON string.
    pub fn encode(&self) -> String {
        self.to_json().to_string()
    }

    /// Parse a string produced by [`Registry::encode`].
    pub fn decode(text: &str) -> Result<Registry, WireError> {
        let v = Json::parse(text).map_err(|e| WireError::new(e.to_string()))?;
        Registry::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};
    use crate::util::stats::Running;

    fn sample_registry() -> Registry {
        let mut r = Registry::new();
        r.inc(MetricKey::with_labels("eva_frames_total", &[("stream", "cam0")]), 10);
        r.inc(MetricKey::with_labels("eva_frames_total", &[("stream", "cam1")]), 4);
        r.inc(MetricKey::new("eva_decode_errors_total"), 1);
        r.set_gauge(MetricKey::new("eva_queue_depth"), 3.5);
        for v in [0.002, 0.004, 0.05, 2.0] {
            r.observe(MetricKey::with_labels("eva_stage_seconds", &[("stage", "detect")]), v);
        }
        r
    }

    #[test]
    fn empty_histogram_quantiles_are_zero() {
        let h = Histogram::latency();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p50(), 0.0);
        assert_eq!(h.p99(), 0.0);
        assert_eq!(h.pct(0.0), 0.0);
        assert!(h.bucket_counts().iter().all(|&c| c == 0));
    }

    #[test]
    fn single_sample_is_every_quantile() {
        let mut h = Histogram::latency();
        h.observe(0.125);
        assert_eq!(h.count(), 1);
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(h.pct(p), 0.125, "p{p}");
        }
        assert_eq!(h.sum(), 0.125);
        assert_eq!(h.bucket_counts().iter().sum::<u64>(), 1);
    }

    #[test]
    fn bucket_boundary_values_land_in_their_bucket() {
        // `value <= bound` counts toward the bucket: an observation
        // exactly on a bound must not spill into the next one.
        let mut h = Histogram::with_bounds(vec![1.0, 2.0, 4.0]);
        h.observe(1.0);
        h.observe(2.0);
        h.observe(2.0000001);
        h.observe(4.0);
        assert_eq!(h.bucket_counts(), &[1, 1, 2, 0]);
    }

    #[test]
    fn overflow_bucket_saturates_instead_of_wrapping() {
        let mut h = Histogram::with_bounds(vec![1.0]);
        h.observe(5.0);
        assert_eq!(h.bucket_counts(), &[0, 1]);
        // Pin the overflow bucket one shy of the ceiling (direct field
        // access — same module): further observations and merges must
        // saturate, not wrap to zero.
        h.counts[1] = u64::MAX - 1;
        h.observe(7.0);
        assert_eq!(h.bucket_counts()[1], u64::MAX);
        h.observe(7.0);
        assert_eq!(h.bucket_counts()[1], u64::MAX);
        let mut other = Histogram::with_bounds(vec![1.0]);
        other.observe(9.0);
        h.merge(&other);
        assert_eq!(h.bucket_counts()[1], u64::MAX);
    }

    #[test]
    fn prop_histogram_quantiles_match_running_on_random_data() {
        // Cross-check the exact-quantile reservoir against the Welford
        // accumulator: count/min/max/mean must agree on arbitrary data.
        check("histogram vs running", Config::default(), |rng| {
            let n = 1 + rng.below(200) as usize;
            let mut h = Histogram::latency();
            let mut r = Running::new();
            let mut p = crate::util::stats::Percentiles::new();
            for _ in 0..n {
                let v = rng.range(1e-4, 50.0);
                h.observe(v);
                r.push(v);
                p.push(v);
            }
            if h.count() != r.count() {
                return Err(format!("count {} vs {}", h.count(), r.count()));
            }
            if (h.pct(0.0) - r.min()).abs() > 1e-12 {
                return Err(format!("min {} vs {}", h.pct(0.0), r.min()));
            }
            if (h.pct(100.0) - r.max()).abs() > 1e-12 {
                return Err(format!("max {} vs {}", h.pct(100.0), r.max()));
            }
            if (h.sum() / h.count() as f64 - r.mean()).abs() > 1e-9 {
                return Err(format!("mean {} vs {}", h.sum() / h.count() as f64, r.mean()));
            }
            for pctl in [25.0, 50.0, 90.0, 99.0] {
                if h.pct(pctl) != p.pct(pctl) {
                    return Err(format!("p{pctl}: {} vs {}", h.pct(pctl), p.pct(pctl)));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn reservoir_is_bounded_past_the_cap_and_exact_below_it() {
        let mut h = Histogram::latency();
        for i in 0..RESERVOIR_CAP {
            h.observe(1e-3 + i as f64 * 1e-6);
        }
        // At the cap: still exact, nothing thinned.
        assert_eq!(h.retained(), RESERVOIR_CAP);
        assert_eq!(h.stride(), 1);
        // Push well past it: memory stays bounded, counters stay exact.
        let total = 5 * RESERVOIR_CAP;
        for i in RESERVOIR_CAP..total {
            h.observe(1e-3 + i as f64 * 1e-6);
        }
        assert!(h.retained() <= RESERVOIR_CAP, "retained {}", h.retained());
        assert!(h.stride() > 1);
        assert_eq!(h.count(), total as u64);
        assert_eq!(h.bucket_counts().iter().sum::<u64>(), total as u64);
        let expected_sum: f64 = (0..total).map(|i| 1e-3 + i as f64 * 1e-6).sum();
        assert!((h.sum() - expected_sum).abs() < 1e-6);
    }

    #[test]
    fn compacted_percentiles_stay_close_to_the_truth() {
        // A deterministic ramp 20× the cap: stride thinning keeps an
        // evenly-spaced subset, so quantiles of the thinned reservoir
        // sit within 1% (relative) of the true order statistics.
        let n = 20 * RESERVOIR_CAP;
        let mut h = Histogram::latency();
        for i in 0..n {
            h.observe((i + 1) as f64 / n as f64);
        }
        for p in [10.0, 50.0, 90.0, 99.0] {
            let truth = p / 100.0;
            let got = h.pct(p);
            assert!(
                (got - truth).abs() <= 0.01 * truth.max(0.1),
                "p{p}: got {got}, truth {truth}"
            );
        }
        assert_eq!(h.pct(100.0), 1.0, "the ramp's maximum is retained");
    }

    #[test]
    fn merged_compacted_histograms_stay_bounded_and_account_everything() {
        let mk = |offset: f64, n: usize| {
            let mut h = Histogram::latency();
            for i in 0..n {
                h.observe(offset + i as f64 * 1e-5);
            }
            h
        };
        let mut a = mk(0.001, 3 * RESERVOIR_CAP);
        let b = mk(0.002, 2 * RESERVOIR_CAP);
        let (ca, cb) = (a.count(), b.count());
        a.merge(&b);
        assert_eq!(a.count(), ca + cb);
        assert!(a.retained() <= RESERVOIR_CAP);
        assert_eq!(
            a.bucket_counts().iter().sum::<u64>(),
            ca + cb,
            "bucket counts merge exactly regardless of thinning"
        );
        // Small merges stay exact: both under the cap, nothing thinned.
        let mut small = mk(0.001, 10);
        small.merge(&mk(0.002, 10));
        assert_eq!(small.retained(), 20);
        assert_eq!(small.stride(), 1);
    }

    #[test]
    fn compacted_snapshot_roundtrips_exactly() {
        let mut reg = Registry::new();
        let key = MetricKey::with_labels("eva_e2e_seconds", &[("shard", "0")]);
        for i in 0..(3 * RESERVOIR_CAP) {
            reg.observe(key.clone(), 1e-3 + (i % 977) as f64 * 1e-5);
        }
        let text = reg.encode();
        let back = Registry::decode(&text).expect("decode");
        assert_eq!(back, reg);
        assert_eq!(back.encode(), text);
        let h = back.histogram(&key).expect("histogram");
        assert_eq!(h.count(), 3 * RESERVOIR_CAP as u64);
        assert!(h.stride() > 1);
    }

    #[test]
    fn pre_compaction_snapshots_without_reservoir_fields_still_decode() {
        // Older snapshots carry no observed/stride keys: they default to
        // "every sample retained, stride 1".
        let v = Json::parse(r#"{"bounds":[1,2],"counts":[1,0,1],"sum":3.5,"samples":[0.5,3]}"#)
            .expect("parse");
        let h = Histogram::from_json(&v).expect("decode");
        assert_eq!(h.count(), 2);
        assert_eq!(h.stride(), 1);
        assert_eq!(h.pct(100.0), 3.0);
        // A reservoir claiming more samples than the cap is malformed.
        let huge: Vec<String> = (0..=RESERVOIR_CAP).map(|i| format!("{i}")).collect();
        let doc = format!(
            r#"{{"bounds":[1],"counts":[0,0],"sum":0,"samples":[{}]}}"#,
            huge.join(",")
        );
        assert!(Histogram::from_json(&Json::parse(&doc).expect("parse")).is_err());
        // And a sub-1 stride is rejected rather than wrapped to zero.
        let bad = Json::parse(
            r#"{"bounds":[1],"counts":[0,0],"sum":0,"samples":[],"stride":0}"#,
        )
        .expect("parse");
        assert!(Histogram::from_json(&bad).is_err());
    }

    #[test]
    fn merge_adds_counters_and_folds_histograms() {
        let mut a = sample_registry();
        let b = sample_registry();
        a.merge(&b);
        assert_eq!(
            a.counter(&MetricKey::with_labels("eva_frames_total", &[("stream", "cam0")])),
            20
        );
        assert_eq!(a.counter_family_total("eva_frames_total"), 28);
        let h = a
            .histogram(&MetricKey::with_labels("eva_stage_seconds", &[("stage", "detect")]))
            .expect("histogram");
        assert_eq!(h.count(), 8);
    }

    #[test]
    fn snapshot_roundtrips_exactly() {
        let reg = sample_registry();
        let text = reg.encode();
        let back = Registry::decode(&text).expect("decode");
        assert_eq!(back, reg, "snapshot text: {text}");
        // Re-encoding the decoded registry is byte-identical: the
        // snapshot is deterministic, not just equivalent.
        assert_eq!(back.encode(), text);
    }

    #[test]
    fn snapshot_rejects_malformed_documents() {
        assert!(Registry::decode("not json").is_err());
        assert!(Registry::decode("{}").is_err());
        let bad_version = sample_registry().encode().replacen("\"format\":1", "\"format\":9", 1);
        assert!(Registry::decode(&bad_version).is_err());
    }

    #[test]
    fn schema_lock_text_exposition_and_json_agree() {
        // CI schema lock: every metric family name and label set in the
        // JSON snapshot appears in the text exposition (and vice versa —
        // the exposition has no families the snapshot lacks), so a
        // renamed metric cannot slip through one format unnoticed.
        let reg = sample_registry();
        let text = reg.text_exposition();
        let snap = reg.to_json();
        for section in ["counters", "gauges", "histograms"] {
            for s in snap.get(section).and_then(Json::as_arr).expect(section) {
                let name = s.get("name").and_then(Json::as_str).expect("name");
                assert!(text.contains(name), "{section} family {name} missing from text");
                for (k, v) in s.get("labels").and_then(Json::as_obj).expect("labels") {
                    let pair = format!("{k}=\"{}\"", v.as_str().expect("label"));
                    assert!(text.contains(&pair), "label {pair} missing from text");
                }
            }
        }
        // TYPE headers are present and typed correctly.
        assert!(text.contains("# TYPE eva_frames_total counter"));
        assert!(text.contains("# TYPE eva_queue_depth gauge"));
        assert!(text.contains("# TYPE eva_stage_seconds histogram"));
        // Histogram series carry the cumulative +Inf bucket and the
        // sum/count pair.
        assert!(text.contains("le=\"+Inf\""));
        assert!(text.contains("eva_stage_seconds_sum"));
        assert!(text.contains("eva_stage_seconds_count"));
        // And the exposition parses back: every sample line's family is
        // declared by a TYPE header above it.
        let mut declared = Vec::new();
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                declared.push(rest.split(' ').next().unwrap().to_string());
            } else if !line.is_empty() {
                let family = line.split(['{', ' ']).next().unwrap();
                let base = family
                    .trim_end_matches("_bucket")
                    .trim_end_matches("_sum")
                    .trim_end_matches("_count");
                assert!(
                    declared.iter().any(|d| d == family || d == base),
                    "undeclared family in line: {line}"
                );
            }
        }
    }

    #[test]
    fn exposition_is_deterministic_across_insertion_orders() {
        let mut a = Registry::new();
        a.inc(MetricKey::with_labels("f", &[("s", "0")]), 1);
        a.inc(MetricKey::with_labels("f", &[("s", "1")]), 2);
        let mut b = Registry::new();
        b.inc(MetricKey::with_labels("f", &[("s", "1")]), 2);
        b.inc(MetricKey::with_labels("f", &[("s", "0")]), 1);
        assert_eq!(a.text_exposition(), b.text_exposition());
        assert_eq!(a.encode(), b.encode());
    }
}
