//! Replayable control-plane event log.
//!
//! An [`EventLog`] is an ordered sequence of [`WireEvent`]s wrapped in a
//! versioned envelope (`{"format": 1, "events": [...]}`). It is both the
//! audit trail of a run (every applied action, origin-tagged) and a
//! replay script: [`EventLog::scripted_events`] lowers the action
//! payloads back into [`ControlEvent`]s that
//! [`crate::fleet::sim::Scenario::with_events`] replays verbatim —
//! feedback-controlled runs become deterministic scripted runs, and a
//! log shipped across a process boundary drives a remote fleet exactly
//! as the local one.

use std::collections::BTreeMap;

use crate::control::plane::{ControlEvent, ControlRecord};
use crate::control::wire::{WireError, WireEvent, WIRE_VERSION};
use crate::util::json::Json;

/// Ordered, versioned sequence of wire events.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EventLog {
    pub events: Vec<WireEvent>,
}

impl EventLog {
    pub fn new() -> EventLog {
        EventLog { events: Vec::new() }
    }

    /// Build from an engine's applied-action records.
    pub fn from_records(records: &[ControlRecord]) -> EventLog {
        EventLog {
            events: records
                .iter()
                .map(|r| WireEvent::action(r.at, r.origin, r.action.clone()))
                .collect(),
        }
    }

    pub fn push(&mut self, event: WireEvent) {
        self.events.push(event);
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Lower the action payloads into scripted [`ControlEvent`]s, in log
    /// order (decision payloads are audit-only and skipped). Feeding
    /// these to [`crate::fleet::sim::Scenario::with_events`] replays the
    /// run's control plane.
    pub fn scripted_events(&self) -> Vec<ControlEvent> {
        self.events
            .iter()
            .filter_map(|e| {
                e.as_action().map(|a| ControlEvent {
                    at: e.at,
                    action: a.clone(),
                })
            })
            .collect()
    }

    /// Human labels in log order (debugging / examples).
    pub fn labels(&self) -> Vec<String> {
        self.events.iter().map(|e| e.label()).collect()
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("format".to_string(), Json::Num(WIRE_VERSION as f64));
        o.insert(
            "events".to_string(),
            Json::Arr(self.events.iter().map(|e| e.to_json()).collect()),
        );
        Json::Obj(o)
    }

    pub fn from_json(v: &Json) -> Result<EventLog, WireError> {
        let format = v
            .get("format")
            .and_then(Json::as_i64)
            .ok_or_else(|| WireError::new("missing log format"))?;
        if format != WIRE_VERSION {
            return Err(WireError::new(format!(
                "unsupported wire format {format} (expected {WIRE_VERSION})"
            )));
        }
        let raw = v
            .get("events")
            .and_then(Json::as_arr)
            .ok_or_else(|| WireError::new("missing events array"))?;
        let mut events = Vec::with_capacity(raw.len());
        for e in raw {
            events.push(WireEvent::from_json(e)?);
        }
        Ok(EventLog { events })
    }

    /// Serialise the whole log to a compact JSON string.
    pub fn encode(&self) -> String {
        self.to_json().to_string()
    }

    /// Parse a string produced by [`EventLog::encode`].
    pub fn decode(text: &str) -> Result<EventLog, WireError> {
        let v = Json::parse(text).map_err(|e| WireError::new(e.to_string()))?;
        EventLog::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::plane::{ControlAction, ControlOrigin};
    use crate::fleet::admission::Decision;
    use crate::fleet::stream::StreamSpec;

    fn sample_log() -> EventLog {
        let mut log = EventLog::new();
        log.push(WireEvent::action(
            0.0,
            ControlOrigin::Placement,
            ControlAction::AttachStream(StreamSpec::new("cam0", 5.0, 100)),
        ));
        log.push(WireEvent::decision(0.0, 0, Decision::Admit { share: 5.0 }));
        log.push(WireEvent::action(
            10.0,
            ControlOrigin::Controller,
            ControlAction::SwapModel { stream: 0, rung: 1 },
        ));
        log.push(WireEvent::action(
            20.0,
            ControlOrigin::Scripted,
            ControlAction::DetachStream(0),
        ));
        log
    }

    #[test]
    fn encode_decode_identity() {
        let log = sample_log();
        let text = log.encode();
        let back = EventLog::decode(&text).expect("decode");
        assert_eq!(back, log);
    }

    #[test]
    fn scripted_events_skip_decisions_and_keep_order() {
        let log = sample_log();
        let events = log.scripted_events();
        assert_eq!(events.len(), 3);
        assert_eq!(events[0].at, 0.0);
        assert!(matches!(events[0].action, ControlAction::AttachStream(_)));
        assert!(matches!(events[1].action, ControlAction::SwapModel { .. }));
        assert!(matches!(events[2].action, ControlAction::DetachStream(0)));
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let log = sample_log();
        let text = log.encode().replace("\"format\":1", "\"format\":99");
        let err = EventLog::decode(&text).unwrap_err();
        assert!(err.msg.contains("unsupported wire format"), "{err}");
    }

    #[test]
    fn from_records_preserves_origin() {
        let records = vec![ControlRecord {
            at: 3.0,
            action: ControlAction::DetachDevice(1),
            origin: ControlOrigin::Controller,
        }];
        let log = EventLog::from_records(&records);
        assert_eq!(log.len(), 1);
        assert_eq!(log.events[0].origin, ControlOrigin::Controller);
        assert_eq!(log.labels(), vec!["detach-device(#1)".to_string()]);
    }
}
