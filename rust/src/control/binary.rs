//! Compact binary payload codec for control-plane hot-path frames.
//!
//! JSON ([`crate::control::wire`]) stays the audit/debug format; this
//! module is the *transport* format for the frames a 100k-stream
//! coordinator sends every epoch — digests, ticks, slices and control
//! events — behind the [`crate::transport::frame::FRAME_VERSION_BINARY`]
//! frame version byte. Design:
//!
//! * **varint integers** — LEB128, so stream/shard ids, epochs, frame
//!   counts and quotas cost 1–2 bytes instead of their decimal JSON
//!   rendering plus a quoted key.
//! * **adaptive floats** — a rate/timestamp whose value survives an
//!   `f32` round trip is shipped as 4 bytes (tag `0`), everything else
//!   as full 8-byte `f64` bits (tag `1`). Decoding is therefore *exact*:
//!   the value read equals the value written bit for bit, which is what
//!   keeps the replayable [`crate::control::EventLog`] contract intact —
//!   a binary-transported event decodes to the identical [`WireEvent`]
//!   the JSON path produces.
//! * **interned strings** — each message carries a string table; the
//!   first occurrence of a name is written literally, every repeat is a
//!   1–2 byte back-reference (rosters and per-stream labels repeat
//!   heavily at scale).
//! * **structured configs ride as compact JSON** — the rarely-sent,
//!   deeply nested payloads (admission policy, autoscale/gate configs,
//!   telemetry snapshots) are embedded as their existing compact-JSON
//!   encodings, so their validation rules and exact round-trip semantics
//!   are shared with the audit path by construction.
//!
//! Exact parity with the JSON codec is property-tested here and in
//! [`crate::transport::frame`]: for every [`WireEvent`] and
//! [`TransportMsg`], `decode(encode(m)) == m`, and both codecs decode to
//! equal values.

use crate::control::caps::SessionCaps;
use crate::control::plane::{ControlAction, ControlOrigin};
use crate::control::wire::{
    admission_from_json, admission_to_json, WireError, WireEvent, WirePayload,
};
use crate::device::{DetectorModelId, DeviceInstance, DeviceKind};
use crate::fleet::admission::Decision;
use crate::fleet::stream::StreamSpec;
use crate::gate::GateVerdict;
use crate::telemetry::Registry;
use crate::transport::msg::{SliceStream, TransportMsg};
use crate::util::json::Json;
use std::collections::HashMap;

/// Version byte leading every standalone binary payload; decode rejects
/// a mismatch (same role as the JSON envelope's `format` stamp).
pub const BINARY_VERSION: u8 = 1;

// ---- primitive writer --------------------------------------------------

/// Append-only binary writer with LEB128 varints, adaptive floats and a
/// per-message string intern table.
#[derive(Debug, Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
    interned: HashMap<String, u64>,
}

impl ByteWriter {
    pub fn new() -> ByteWriter {
        ByteWriter::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn u8(&mut self, b: u8) {
        self.buf.push(b);
    }

    /// LEB128 unsigned varint: 7 payload bits per byte, high bit = more.
    pub fn varint(&mut self, mut v: u64) {
        loop {
            let byte = (v & 0x7F) as u8;
            v >>= 7;
            if v == 0 {
                self.buf.push(byte);
                return;
            }
            self.buf.push(byte | 0x80);
        }
    }

    /// Raw little-endian u64 (bit-exact seeds).
    pub fn u64_raw(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn bool(&mut self, b: bool) {
        self.buf.push(b as u8);
    }

    /// Adaptive float: tag `0` + 4 LE bytes when the value survives an
    /// f32 round trip (most rates and small timestamps), tag `1` + 8 LE
    /// bytes otherwise. Decoding is bit-exact either way.
    pub fn f64(&mut self, v: f64) {
        let narrow = v as f32;
        if f64::from(narrow).to_bits() == v.to_bits() {
            self.buf.push(0);
            self.buf.extend_from_slice(&narrow.to_le_bytes());
        } else {
            self.buf.push(1);
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    /// Interned string: varint `0` + len + UTF-8 on first sight, varint
    /// `index + 1` back-reference on every repeat.
    pub fn string(&mut self, s: &str) {
        if let Some(&idx) = self.interned.get(s) {
            self.varint(idx + 1);
            return;
        }
        let idx = self.interned.len() as u64;
        self.interned.insert(s.to_string(), idx);
        self.varint(0);
        self.varint(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// A nested structured payload as its compact JSON text.
    pub fn json(&mut self, v: &Json) {
        let text = v.to_string();
        self.varint(text.len() as u64);
        self.buf.extend_from_slice(text.as_bytes());
    }
}

// ---- primitive reader --------------------------------------------------

/// Mirror of [`ByteWriter`]; every read validates bounds and surfaces
/// malformed input as [`WireError`] (never a panic).
#[derive(Debug)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
    interned: Vec<String>,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader {
            buf,
            pos: 0,
            interned: Vec::new(),
        }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::new("binary payload truncated"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub fn varint(&mut self) -> Result<u64, WireError> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let byte = self.u8()?;
            if shift >= 64 || (shift == 63 && byte > 1) {
                return Err(WireError::new("varint overflows u64"));
            }
            v |= u64::from(byte & 0x7F) << shift;
            if byte & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    pub fn usize(&mut self) -> Result<usize, WireError> {
        Ok(self.varint()? as usize)
    }

    pub fn u64_raw(&mut self) -> Result<u64, WireError> {
        let bytes = self.take(8)?;
        Ok(u64::from_le_bytes(bytes.try_into().expect("8 bytes")))
    }

    pub fn bool(&mut self) -> Result<bool, WireError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(WireError::new(format!("bad bool byte {other}"))),
        }
    }

    pub fn f64(&mut self) -> Result<f64, WireError> {
        match self.u8()? {
            0 => {
                let bytes = self.take(4)?;
                Ok(f64::from(f32::from_le_bytes(
                    bytes.try_into().expect("4 bytes"),
                )))
            }
            1 => {
                let bytes = self.take(8)?;
                Ok(f64::from_le_bytes(bytes.try_into().expect("8 bytes")))
            }
            other => Err(WireError::new(format!("bad float width tag {other}"))),
        }
    }

    pub fn string(&mut self) -> Result<String, WireError> {
        let tag = self.varint()?;
        if tag == 0 {
            let len = self.usize()?;
            let bytes = self.take(len)?;
            let s = std::str::from_utf8(bytes)
                .map_err(|_| WireError::new("interned string is not UTF-8"))?
                .to_string();
            self.interned.push(s.clone());
            return Ok(s);
        }
        let idx = (tag - 1) as usize;
        self.interned
            .get(idx)
            .cloned()
            .ok_or_else(|| WireError::new(format!("string back-reference {idx} out of range")))
    }

    pub fn json(&mut self) -> Result<Json, WireError> {
        let len = self.usize()?;
        let bytes = self.take(len)?;
        let text = std::str::from_utf8(bytes)
            .map_err(|_| WireError::new("embedded JSON is not UTF-8"))?;
        Json::parse(text).map_err(|e| WireError::new(e.to_string()))
    }
}

// ---- enum tags ---------------------------------------------------------

fn origin_tag(origin: ControlOrigin) -> u8 {
    match origin {
        ControlOrigin::Scripted => 0,
        ControlOrigin::Controller => 1,
        ControlOrigin::Placement => 2,
        ControlOrigin::Admission => 3,
        ControlOrigin::Gate => 4,
    }
}

fn origin_from_tag(tag: u8) -> Result<ControlOrigin, WireError> {
    Ok(match tag {
        0 => ControlOrigin::Scripted,
        1 => ControlOrigin::Controller,
        2 => ControlOrigin::Placement,
        3 => ControlOrigin::Admission,
        4 => ControlOrigin::Gate,
        other => return Err(WireError::new(format!("unknown origin tag {other}"))),
    })
}

fn kind_tag(kind: DeviceKind) -> u8 {
    match kind {
        DeviceKind::Ncs2 => 0,
        DeviceKind::FastCpu => 1,
        DeviceKind::SlowCpu => 2,
        DeviceKind::TitanX => 3,
    }
}

fn kind_from_tag(tag: u8) -> Result<DeviceKind, WireError> {
    Ok(match tag {
        0 => DeviceKind::Ncs2,
        1 => DeviceKind::FastCpu,
        2 => DeviceKind::SlowCpu,
        3 => DeviceKind::TitanX,
        other => return Err(WireError::new(format!("unknown device kind tag {other}"))),
    })
}

fn model_tag(model: DetectorModelId) -> u8 {
    match model {
        DetectorModelId::Ssd300 => 0,
        DetectorModelId::Yolov3 => 1,
    }
}

fn model_from_tag(tag: u8) -> Result<DetectorModelId, WireError> {
    Ok(match tag {
        0 => DetectorModelId::Ssd300,
        1 => DetectorModelId::Yolov3,
        other => return Err(WireError::new(format!("unknown model tag {other}"))),
    })
}

// ---- nested structs ----------------------------------------------------

fn write_spec(w: &mut ByteWriter, spec: &StreamSpec) {
    w.string(&spec.name);
    w.f64(spec.fps);
    w.varint(spec.num_frames);
    w.f64(spec.weight);
    w.varint(spec.window as u64);
    // Presence-flagged periodic rate profile (same idiom as a device's
    // rate_override; this codec is gated by BINARY_VERSION, unlike the
    // JSON twin whose absent-key contract carries the compatibility).
    match &spec.profile {
        Some(p) => {
            w.bool(true);
            w.f64(p.period);
            w.varint(p.mults.len() as u64);
            for &m in &p.mults {
                w.f64(m);
            }
        }
        None => w.bool(false),
    }
}

fn read_spec(r: &mut ByteReader) -> Result<StreamSpec, WireError> {
    let name = r.string()?;
    let fps = r.f64()?;
    if !fps.is_finite() || fps <= 0.0 {
        return Err(WireError::new("stream fps must be positive"));
    }
    let num_frames = r.varint()?;
    let weight = r.f64()?;
    if !weight.is_finite() || weight <= 0.0 {
        return Err(WireError::new("stream weight must be positive"));
    }
    let window = r.usize()?.max(1);
    let mut spec = StreamSpec::new(&name, fps, num_frames);
    spec.weight = weight;
    spec.window = window;
    if r.bool()? {
        let period = r.f64()?;
        if !period.is_finite() || period <= 0.0 {
            return Err(WireError::new("rate profile period must be positive"));
        }
        let count = r.usize()?;
        if count == 0 {
            return Err(WireError::new("rate profile needs at least one bucket"));
        }
        let mut mults = Vec::with_capacity(count.min(1 << 16));
        for _ in 0..count {
            let m = r.f64()?;
            if !m.is_finite() || m <= 0.0 {
                return Err(WireError::new("rate profile multipliers must be positive"));
            }
            mults.push(m);
        }
        spec.profile = Some(crate::fleet::stream::RateProfile { period, mults });
    }
    Ok(spec)
}

fn write_device(w: &mut ByteWriter, d: &DeviceInstance) {
    w.u8(kind_tag(d.kind));
    w.u8(model_tag(d.model));
    w.varint(d.replica as u64);
    w.f64(d.jitter_cv);
    match d.rate_override {
        Some(rate) => {
            w.bool(true);
            w.f64(rate);
        }
        None => w.bool(false),
    }
}

fn read_device(r: &mut ByteReader) -> Result<DeviceInstance, WireError> {
    let kind = kind_from_tag(r.u8()?)?;
    let model = model_from_tag(r.u8()?)?;
    let replica = r.usize()?;
    let mut d = DeviceInstance::new(kind, model, replica);
    d.jitter_cv = r.f64()?;
    d.rate_override = if r.bool()? { Some(r.f64()?) } else { None };
    Ok(d)
}

fn write_decision(w: &mut ByteWriter, d: &Decision) {
    match d {
        Decision::Admit { share } => {
            w.u8(0);
            w.f64(*share);
        }
        Decision::Degrade { stride, share } => {
            w.u8(1);
            w.varint(*stride);
            w.f64(*share);
        }
        Decision::SwapModel { rung, stride, share } => {
            w.u8(2);
            w.varint(*rung as u64);
            w.varint(*stride);
            w.f64(*share);
        }
        Decision::Reject => w.u8(3),
    }
}

fn read_decision(r: &mut ByteReader) -> Result<Decision, WireError> {
    Ok(match r.u8()? {
        0 => Decision::Admit { share: r.f64()? },
        1 => Decision::Degrade {
            stride: r.varint()?,
            share: r.f64()?,
        },
        2 => Decision::SwapModel {
            rung: r.usize()?,
            stride: r.varint()?,
            share: r.f64()?,
        },
        3 => Decision::Reject,
        other => return Err(WireError::new(format!("unknown decision tag {other}"))),
    })
}

fn write_verdict(w: &mut ByteWriter, v: &GateVerdict) {
    match v {
        GateVerdict::Detect => w.u8(0),
        GateVerdict::SceneCut => w.u8(1),
        GateVerdict::SkipCap => w.u8(2),
        GateVerdict::Skip => w.u8(3),
        GateVerdict::DownRung(rung) => {
            w.u8(4);
            w.varint(*rung as u64);
        }
    }
}

fn read_verdict(r: &mut ByteReader) -> Result<GateVerdict, WireError> {
    Ok(match r.u8()? {
        0 => GateVerdict::Detect,
        1 => GateVerdict::SceneCut,
        2 => GateVerdict::SkipCap,
        3 => GateVerdict::Skip,
        4 => GateVerdict::DownRung(r.usize()?),
        other => return Err(WireError::new(format!("unknown gate verdict tag {other}"))),
    })
}

// ---- WireEvent ---------------------------------------------------------

/// Write one event (no leading version byte) into an existing writer —
/// shared by the standalone event codec and `TransportMsg::Control`.
fn write_event(w: &mut ByteWriter, ev: &WireEvent) {
    w.f64(ev.at);
    w.u8(origin_tag(ev.origin));
    match &ev.payload {
        WirePayload::Action(ControlAction::AttachStream(spec)) => {
            w.u8(0);
            write_spec(w, spec);
        }
        WirePayload::Action(ControlAction::DetachStream(id)) => {
            w.u8(1);
            w.varint(*id as u64);
        }
        WirePayload::Action(ControlAction::AttachDevice(d)) => {
            w.u8(2);
            write_device(w, d);
        }
        WirePayload::Action(ControlAction::DetachDevice(dev)) => {
            w.u8(3);
            w.varint(*dev as u64);
        }
        WirePayload::Action(ControlAction::SwapModel { stream, rung }) => {
            w.u8(4);
            w.varint(*stream as u64);
            w.varint(*rung as u64);
        }
        WirePayload::Decision { stream, decision } => {
            w.u8(5);
            w.varint(*stream as u64);
            write_decision(w, decision);
        }
        WirePayload::Gate { stream, frame, verdict } => {
            w.u8(6);
            w.varint(*stream as u64);
            w.varint(*frame);
            write_verdict(w, verdict);
        }
    }
}

fn read_event(r: &mut ByteReader) -> Result<WireEvent, WireError> {
    let at = r.f64()?;
    let origin = origin_from_tag(r.u8()?)?;
    let payload = match r.u8()? {
        0 => WirePayload::Action(ControlAction::AttachStream(read_spec(r)?)),
        1 => WirePayload::Action(ControlAction::DetachStream(r.usize()?)),
        2 => WirePayload::Action(ControlAction::AttachDevice(read_device(r)?)),
        3 => WirePayload::Action(ControlAction::DetachDevice(r.usize()?)),
        4 => WirePayload::Action(ControlAction::SwapModel {
            stream: r.usize()?,
            rung: r.usize()?,
        }),
        5 => WirePayload::Decision {
            stream: r.usize()?,
            decision: read_decision(r)?,
        },
        6 => WirePayload::Gate {
            stream: r.usize()?,
            frame: r.varint()?,
            verdict: read_verdict(r)?,
        },
        other => return Err(WireError::new(format!("unknown event payload tag {other}"))),
    };
    Ok(WireEvent { at, origin, payload })
}

/// Encode one [`WireEvent`] as a standalone binary payload.
pub fn encode_event(ev: &WireEvent) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.u8(BINARY_VERSION);
    write_event(&mut w, ev);
    w.into_bytes()
}

/// Decode a standalone binary payload produced by [`encode_event`].
pub fn decode_event(bytes: &[u8]) -> Result<WireEvent, WireError> {
    let mut r = ByteReader::new(bytes);
    let version = r.u8()?;
    if version != BINARY_VERSION {
        return Err(WireError::new(format!(
            "unsupported binary payload version {version}"
        )));
    }
    let ev = read_event(&mut r)?;
    if r.remaining() > 0 {
        return Err(WireError::new("trailing bytes after event"));
    }
    Ok(ev)
}

// ---- TransportMsg ------------------------------------------------------

const MSG_HELLO: u8 = 0;
const MSG_WELCOME: u8 = 1;
const MSG_CONTROL: u8 = 2;
const MSG_POLL: u8 = 3;
const MSG_DIGEST: u8 = 4;
const MSG_TICK: u8 = 5;
const MSG_SLICE: u8 = 6;
const MSG_TELEMETRY: u8 = 7;
const MSG_BYE: u8 = 8;
const MSG_REJECT: u8 = 9;

/// Encode one [`TransportMsg`] as a binary frame payload.
pub fn encode_msg(msg: &TransportMsg) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.u8(BINARY_VERSION);
    match msg {
        TransportMsg::Hello {
            shard,
            protocol,
            admission,
            roster,
            caps,
        } => {
            w.u8(MSG_HELLO);
            w.varint(*shard as u64);
            w.varint(*protocol as u64);
            w.json(&admission_to_json(admission));
            w.varint(roster.len() as u64);
            for name in roster {
                w.string(name);
            }
            // The capability set rides as its one JSON rendering in
            // both codecs — a single forward-compatibility surface
            // (handshakes are rare; compactness does not matter here).
            w.json(&caps.to_json());
        }
        TransportMsg::Welcome { shard, capacity } => {
            w.u8(MSG_WELCOME);
            w.varint(*shard as u64);
            w.f64(*capacity);
        }
        TransportMsg::Reject { code, detail } => {
            w.u8(MSG_REJECT);
            w.string(code);
            w.string(detail);
        }
        TransportMsg::Control(ev) => {
            w.u8(MSG_CONTROL);
            write_event(&mut w, ev);
        }
        TransportMsg::Poll { epoch, at } => {
            w.u8(MSG_POLL);
            w.varint(*epoch as u64);
            w.f64(*at);
        }
        TransportMsg::Digest {
            shard,
            at,
            capacity,
            committed,
            forecast,
        } => {
            w.u8(MSG_DIGEST);
            w.varint(*shard as u64);
            w.f64(*at);
            w.f64(*capacity);
            w.f64(*committed);
            // Forecast-Σλ rides as an optional *trailing* section: absent
            // forecasts write nothing, so forecast-free runs stay
            // byte-identical to pre-forecast builds and legacy digests
            // (which end at `committed`) decode with the slot absent.
            if let Some(f) = forecast {
                w.bool(true);
                w.f64(*f);
            }
        }
        TransportMsg::Tick {
            epoch,
            at,
            seed,
            quotas,
        } => {
            w.u8(MSG_TICK);
            w.varint(*epoch as u64);
            w.f64(*at);
            w.u64_raw(*seed);
            w.varint(quotas.len() as u64);
            for &(id, frames) in quotas {
                w.varint(id as u64);
                w.varint(frames);
            }
        }
        TransportMsg::Slice {
            epoch,
            busy,
            frames,
            streams,
        } => {
            w.u8(MSG_SLICE);
            w.varint(*epoch as u64);
            w.f64(*busy);
            w.varint(*frames);
            w.varint(streams.len() as u64);
            for s in streams {
                w.varint(s.id as u64);
                w.varint(s.total);
                w.varint(s.processed);
                w.varint(s.latencies.len() as u64);
                for &l in &s.latencies {
                    w.f64(l);
                }
            }
        }
        TransportMsg::Telemetry {
            shard,
            epoch,
            snapshot,
        } => {
            w.u8(MSG_TELEMETRY);
            w.varint(*shard as u64);
            w.varint(*epoch as u64);
            w.json(&snapshot.to_json());
        }
        TransportMsg::Bye => w.u8(MSG_BYE),
    }
    w.into_bytes()
}

/// Decode a binary frame payload produced by [`encode_msg`].
pub fn decode_msg(bytes: &[u8]) -> Result<TransportMsg, WireError> {
    let mut r = ByteReader::new(bytes);
    let version = r.u8()?;
    if version != BINARY_VERSION {
        return Err(WireError::new(format!(
            "unsupported binary payload version {version}"
        )));
    }
    let msg = match r.u8()? {
        MSG_HELLO => {
            let shard = r.usize()?;
            let protocol = r.varint()? as i64;
            let admission = admission_from_json(&r.json()?)?;
            let count = r.usize()?;
            let mut roster = Vec::with_capacity(count.min(1 << 16));
            for _ in 0..count {
                roster.push(r.string()?);
            }
            let caps = SessionCaps::from_json(&r.json()?)?;
            TransportMsg::Hello {
                shard,
                protocol,
                admission,
                roster,
                caps,
            }
        }
        MSG_WELCOME => TransportMsg::Welcome {
            shard: r.usize()?,
            capacity: r.f64()?,
        },
        MSG_REJECT => TransportMsg::Reject {
            code: r.string()?,
            detail: r.string()?,
        },
        MSG_CONTROL => TransportMsg::Control(read_event(&mut r)?),
        MSG_POLL => TransportMsg::Poll {
            epoch: r.usize()?,
            at: r.f64()?,
        },
        MSG_DIGEST => {
            let shard = r.usize()?;
            let at = r.f64()?;
            let capacity = r.f64()?;
            let committed = r.f64()?;
            // Legacy digests end here; the forecast slot is a trailing
            // optional section.
            let forecast = if r.remaining() > 0 {
                if r.bool()? { Some(r.f64()?) } else { None }
            } else {
                None
            };
            TransportMsg::Digest {
                shard,
                at,
                capacity,
                committed,
                forecast,
            }
        }
        MSG_TICK => {
            let epoch = r.usize()?;
            let at = r.f64()?;
            let seed = r.u64_raw()?;
            let count = r.usize()?;
            let mut quotas = Vec::with_capacity(count.min(1 << 16));
            for _ in 0..count {
                quotas.push((r.usize()?, r.varint()?));
            }
            TransportMsg::Tick {
                epoch,
                at,
                seed,
                quotas,
            }
        }
        MSG_SLICE => {
            let epoch = r.usize()?;
            let busy = r.f64()?;
            let frames = r.varint()?;
            let count = r.usize()?;
            let mut streams = Vec::with_capacity(count.min(1 << 16));
            for _ in 0..count {
                let id = r.usize()?;
                let total = r.varint()?;
                let processed = r.varint()?;
                let lat_count = r.usize()?;
                let mut latencies = Vec::with_capacity(lat_count.min(1 << 16));
                for _ in 0..lat_count {
                    latencies.push(r.f64()?);
                }
                streams.push(SliceStream {
                    id,
                    total,
                    processed,
                    latencies,
                });
            }
            TransportMsg::Slice {
                epoch,
                busy,
                frames,
                streams,
            }
        }
        MSG_TELEMETRY => TransportMsg::Telemetry {
            shard: r.usize()?,
            epoch: r.usize()?,
            snapshot: Registry::from_json(&r.json()?)?,
        },
        MSG_BYE => TransportMsg::Bye,
        other => return Err(WireError::new(format!("unknown transport message tag {other}"))),
    };
    if r.remaining() > 0 {
        return Err(WireError::new("trailing bytes after message"));
    }
    Ok(msg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::admission::AdmissionPolicy;
    use crate::transport::msg::TRANSPORT_VERSION;
    use crate::util::prop::{check, Config};
    use crate::util::Rng;

    #[test]
    fn primitives_roundtrip() {
        let mut w = ByteWriter::new();
        w.varint(0);
        w.varint(127);
        w.varint(128);
        w.varint(u64::MAX);
        w.u64_raw(0xDEAD_BEEF_CAFE_F00D);
        w.f64(2.5); // f32-exact → narrow
        w.f64(0.1); // not f32-exact → wide
        w.bool(true);
        w.string("cam0");
        w.string("cam1");
        w.string("cam0"); // back-reference
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.varint().unwrap(), 0);
        assert_eq!(r.varint().unwrap(), 127);
        assert_eq!(r.varint().unwrap(), 128);
        assert_eq!(r.varint().unwrap(), u64::MAX);
        assert_eq!(r.u64_raw().unwrap(), 0xDEAD_BEEF_CAFE_F00D);
        assert_eq!(r.f64().unwrap(), 2.5);
        assert_eq!(r.f64().unwrap(), 0.1);
        assert!(r.bool().unwrap());
        assert_eq!(r.string().unwrap(), "cam0");
        assert_eq!(r.string().unwrap(), "cam1");
        assert_eq!(r.string().unwrap(), "cam0");
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn adaptive_floats_are_bit_exact() {
        // Shortest-round-trip JSON and the adaptive binary float must
        // agree bit for bit on both branches.
        for v in [
            0.0,
            -0.0,
            1.0,
            2.5,
            0.1,
            1.0 / 3.0,
            f64::MIN_POSITIVE,
            1e300,
            -7.25,
        ] {
            let mut w = ByteWriter::new();
            w.f64(v);
            let bytes = w.into_bytes();
            let got = ByteReader::new(&bytes).f64().unwrap();
            assert_eq!(got.to_bits(), v.to_bits(), "value {v}");
        }
    }

    #[test]
    fn truncated_and_garbage_payloads_are_errors_not_panics() {
        let ev = WireEvent::action(
            1.5,
            ControlOrigin::Placement,
            ControlAction::DetachStream(3),
        );
        let bytes = encode_event(&ev);
        for cut in 0..bytes.len() {
            assert!(decode_event(&bytes[..cut]).is_err(), "cut at {cut}");
        }
        // Trailing bytes are rejected, not ignored.
        let mut long = bytes.clone();
        long.push(0);
        assert!(decode_event(&long).is_err());
        // A bogus version byte is rejected up front.
        let mut bad = bytes;
        bad[0] = 99;
        assert!(decode_event(&bad).is_err());
        assert!(decode_msg(&[BINARY_VERSION, 200]).is_err());
    }

    fn arbitrary_event(rng: &mut Rng) -> WireEvent {
        let origin = *rng.choose(&[
            ControlOrigin::Scripted,
            ControlOrigin::Controller,
            ControlOrigin::Placement,
            ControlOrigin::Admission,
        ]);
        let at = rng.range(0.0, 1e4);
        match rng.below(8) {
            0 => {
                let mut spec = StreamSpec::new(
                    &format!("cam{}", rng.below(64)),
                    rng.range(0.5, 40.0),
                    rng.int_in(1, 5_000) as u64,
                )
                .with_weight(rng.range(0.25, 4.0))
                .with_window(rng.int_in(1, 16) as usize);
                if rng.chance(0.3) {
                    let buckets = rng.int_in(1, 8) as usize;
                    spec = spec.with_profile(crate::fleet::stream::RateProfile::new(
                        rng.range(1.0, 240.0),
                        (0..buckets).map(|_| rng.range(0.25, 4.0)).collect(),
                    ));
                }
                WireEvent::action(at, origin, ControlAction::AttachStream(spec))
            }
            1 => WireEvent::action(at, origin, ControlAction::DetachStream(rng.below(1 << 20) as usize)),
            2 => {
                let mut d = DeviceInstance::new(
                    *rng.choose(&[
                        DeviceKind::Ncs2,
                        DeviceKind::FastCpu,
                        DeviceKind::SlowCpu,
                        DeviceKind::TitanX,
                    ]),
                    *rng.choose(&[DetectorModelId::Ssd300, DetectorModelId::Yolov3]),
                    rng.below(256) as usize,
                );
                d.jitter_cv = rng.range(0.0, 0.3);
                if rng.chance(0.5) {
                    d.rate_override = Some(rng.range(0.5, 60.0));
                }
                WireEvent::action(at, origin, ControlAction::AttachDevice(d))
            }
            3 => WireEvent::action(at, origin, ControlAction::DetachDevice(rng.below(256) as usize)),
            4 => WireEvent::action(
                at,
                origin,
                ControlAction::SwapModel {
                    stream: rng.below(1 << 20) as usize,
                    rung: rng.below(4) as usize,
                },
            ),
            5 => WireEvent::decision(
                at,
                rng.below(1 << 20) as usize,
                match rng.below(4) {
                    0 => Decision::Admit { share: rng.range(0.1, 30.0) },
                    1 => Decision::Degrade {
                        stride: rng.int_in(2, 16) as u64,
                        share: rng.range(0.1, 30.0),
                    },
                    2 => Decision::SwapModel {
                        rung: rng.below(4) as usize,
                        stride: rng.int_in(1, 16) as u64,
                        share: rng.range(0.1, 30.0),
                    },
                    _ => Decision::Reject,
                },
            ),
            6 => WireEvent::gate(
                at,
                rng.below(1 << 20) as usize,
                rng.below(1 << 30),
                *rng.choose(&[
                    GateVerdict::Detect,
                    GateVerdict::SceneCut,
                    GateVerdict::SkipCap,
                    GateVerdict::Skip,
                ]),
            ),
            _ => WireEvent::gate(
                at,
                rng.below(1 << 20) as usize,
                rng.below(1 << 30),
                GateVerdict::DownRung(rng.below(4) as usize),
            ),
        }
    }

    #[test]
    fn prop_events_roundtrip_binary_and_match_the_json_path() {
        // The tentpole parity pin at the event level: the binary codec
        // decodes to the *identical* WireEvent the JSON path produces.
        check("binary event parity", Config::default(), |rng| {
            let ev = arbitrary_event(rng);
            let bin = decode_event(&encode_event(&ev)).map_err(|e| e.to_string())?;
            let json = WireEvent::decode(&ev.encode()).map_err(|e| e.to_string())?;
            if bin != ev {
                return Err(format!("binary round trip: {bin:?} != {ev:?}"));
            }
            if bin != json {
                return Err(format!("codec divergence: {bin:?} != {json:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn binary_events_are_smaller_than_json() {
        // Honest at the event level too, not just for digests: a detach
        // event is a handful of bytes against ~70 of JSON.
        let ev = WireEvent::action(
            12.5,
            ControlOrigin::Placement,
            ControlAction::DetachStream(90_000),
        );
        let bin = encode_event(&ev).len();
        let json = ev.encode().len();
        assert!(
            bin * 3 <= json,
            "binary {bin}B should be ≤ a third of JSON {json}B"
        );
    }

    #[test]
    fn hello_with_caps_roundtrips_and_interns_the_roster() {
        use crate::autoscale::policy::AutoscaleConfig;
        use crate::gate::GateConfig;
        let msg = TransportMsg::Hello {
            shard: 3,
            protocol: TRANSPORT_VERSION,
            admission: AdmissionPolicy::with_ladder(vec![1.0, 2.6, 3.2]),
            roster: vec!["cam0".into(), "cam1".into(), "cam0".into()],
            caps: SessionCaps {
                autoscale: Some(AutoscaleConfig {
                    max_devices: 7,
                    device_rate: 3.25,
                    ..AutoscaleConfig::default()
                }),
                gate: Some(GateConfig::default()),
                telemetry: true,
                token: Some("s3cret".into()),
                ..SessionCaps::default()
            },
        };
        let bytes = encode_msg(&msg);
        assert_eq!(decode_msg(&bytes).unwrap(), msg);
    }

    #[test]
    fn reject_roundtrips_binary_and_matches_the_json_path() {
        // The typed refusal frame exists precisely so a rejected peer
        // never hangs; both codecs must carry it identically.
        let msg = TransportMsg::Reject {
            code: "auth".into(),
            detail: "bad or missing session token".into(),
        };
        assert_eq!(decode_msg(&encode_msg(&msg)).unwrap(), msg);
        assert_eq!(TransportMsg::decode(&msg.encode()).unwrap(), msg);
    }

    #[test]
    fn tick_seed_is_bit_exact() {
        // The seed that does not survive a JSON f64 must survive the
        // binary codec verbatim (it travels as raw LE bytes).
        let msg = TransportMsg::Tick {
            epoch: 3,
            at: 30.0,
            seed: 0xDEAD_BEEF_CAFE_F00D,
            quotas: vec![(0, 25), (3, 12)],
        };
        match decode_msg(&encode_msg(&msg)).unwrap() {
            TransportMsg::Tick { seed, .. } => assert_eq!(seed, 0xDEAD_BEEF_CAFE_F00D),
            other => panic!("not a tick: {other:?}"),
        }
    }

    #[test]
    fn digest_is_at_least_3x_smaller_than_json() {
        // The scale acceptance pin at the message level: one headroom
        // digest with realistic (non-round) float values.
        let msg = TransportMsg::Digest {
            shard: 137,
            at: 1234.5678901,
            capacity: 9.466666666666667,
            committed: 7.183333333333334,
            forecast: None,
        };
        let bin = encode_msg(&msg).len();
        let json = msg.encode().len();
        assert!(
            bin * 3 <= json,
            "binary digest {bin}B should be ≤ a third of JSON {json}B"
        );
    }
}
