//! The control-plane vocabulary: every verb a fleet accepts at runtime.
//!
//! Before this layer existed, control flow lived as private in-memory
//! enums spread across `fleet::registry` (membership actions),
//! `fleet::sim` (the scripted/controller action log) and
//! `autoscale::runner` (log post-processing). Centralising the types
//! here — and giving them a wire codec in [`crate::control::wire`] —
//! is what lets a control decision cross a process boundary: the shard
//! placement layer ([`crate::shard`]) speaks exactly this vocabulary,
//! serialised, to move streams between fleet instances.

use crate::device::DeviceInstance;
use crate::fleet::stream::{StreamId, StreamSpec};

/// A timed control-plane action — scripted by a scenario
/// ([`crate::fleet::sim::Scenario`]), emitted by a feedback controller
/// ([`crate::fleet::sim::FleetController`]), or issued by the shard
/// placement layer ([`crate::shard`]).
#[derive(Debug, Clone, PartialEq)]
pub enum ControlAction {
    AttachStream(StreamSpec),
    DetachStream(StreamId),
    AttachDevice(DeviceInstance),
    DetachDevice(usize),
    /// Pin stream `stream` to model-ladder rung `rung` (0 = full
    /// quality); the residual stride is recomputed from the stream's
    /// current fair share.
    SwapModel { stream: StreamId, rung: usize },
}

impl ControlAction {
    /// Compact human label for control logs.
    pub fn label(&self) -> String {
        match self {
            ControlAction::AttachStream(spec) => format!("attach-stream({})", spec.name),
            ControlAction::DetachStream(id) => format!("detach-stream(s{id})"),
            ControlAction::AttachDevice(d) => {
                format!("attach-device({:.1} FPS)", d.rate())
            }
            ControlAction::DetachDevice(dev) => format!("detach-device(#{dev})"),
            ControlAction::SwapModel { stream, rung } => {
                format!("swap-model(s{stream} -> rung {rung})")
            }
        }
    }
}

/// `action` applied at fleet time `at`.
#[derive(Debug, Clone, PartialEq)]
pub struct ControlEvent {
    pub at: f64,
    pub action: ControlAction,
}

/// Who issued a control action. Logged with every applied action so
/// post-run analysis (and the wire log) can attribute behaviour to the
/// scenario script, a feedback controller, the shard placement layer,
/// or the admission policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ControlOrigin {
    /// Scenario-scripted event (external load / failures).
    Scripted,
    /// Closed-loop feedback controller (autoscale).
    Controller,
    /// Shard placement layer (initial placement, migration, re-placement
    /// of orphans after shard loss).
    Placement,
    /// Admission policy outcome (wall-clock serve logs decisions).
    Admission,
    /// Per-frame motion gate ([`crate::gate`]): skip / refresh /
    /// down-rung verdicts on individual frames.
    Gate,
}

impl ControlOrigin {
    pub fn label(&self) -> &'static str {
        match self {
            ControlOrigin::Scripted => "scripted",
            ControlOrigin::Controller => "controller",
            ControlOrigin::Placement => "placement",
            ControlOrigin::Admission => "admission",
            ControlOrigin::Gate => "gate",
        }
    }

    pub fn parse(s: &str) -> Option<ControlOrigin> {
        match s {
            "scripted" => Some(ControlOrigin::Scripted),
            "controller" => Some(ControlOrigin::Controller),
            "placement" => Some(ControlOrigin::Placement),
            "admission" => Some(ControlOrigin::Admission),
            "gate" => Some(ControlOrigin::Gate),
            _ => None,
        }
    }
}

/// One applied control-plane action, for post-run analysis.
#[derive(Debug, Clone, PartialEq)]
pub struct ControlRecord {
    pub at: f64,
    pub action: ControlAction,
    pub origin: ControlOrigin,
}

impl ControlRecord {
    /// Back-compat helper: scenario-scripted records.
    pub fn scripted(&self) -> bool {
        self.origin == ControlOrigin::Scripted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{DetectorModelId, DeviceKind};

    #[test]
    fn action_labels() {
        let spec = StreamSpec::new("cam0", 5.0, 100);
        assert_eq!(
            ControlAction::AttachStream(spec).label(),
            "attach-stream(cam0)"
        );
        assert_eq!(ControlAction::DetachStream(3).label(), "detach-stream(s3)");
        let d = DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, 0, 2.5);
        assert_eq!(ControlAction::AttachDevice(d).label(), "attach-device(2.5 FPS)");
        assert_eq!(ControlAction::DetachDevice(1).label(), "detach-device(#1)");
        assert_eq!(
            ControlAction::SwapModel { stream: 2, rung: 1 }.label(),
            "swap-model(s2 -> rung 1)"
        );
    }

    #[test]
    fn origin_labels_roundtrip() {
        for o in [
            ControlOrigin::Scripted,
            ControlOrigin::Controller,
            ControlOrigin::Placement,
            ControlOrigin::Admission,
            ControlOrigin::Gate,
        ] {
            assert_eq!(ControlOrigin::parse(o.label()), Some(o));
        }
        assert_eq!(ControlOrigin::parse("bogus"), None);
    }

    #[test]
    fn record_scripted_helper() {
        let r = ControlRecord {
            at: 1.0,
            action: ControlAction::DetachStream(0),
            origin: ControlOrigin::Scripted,
        };
        assert!(r.scripted());
        let r = ControlRecord {
            origin: ControlOrigin::Placement,
            ..r
        };
        assert!(!r.scripted());
    }
}
