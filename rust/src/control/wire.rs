//! Versioned wire format for control-plane traffic, over
//! [`crate::util::json`].
//!
//! A [`WireEvent`] is one control-plane message: a timed, origin-tagged
//! payload that is either a [`ControlAction`] (the membership / quality
//! verbs) or an admission [`Decision`] outcome. Everything an engine
//! logs or a placement layer sends is expressible as wire events, so a
//! control decision can cross a process boundary as JSON and be applied
//! on the far side exactly as an in-memory action would be.
//!
//! Guarantees (property- and unit-tested here and in
//! `rust/tests/integration_shard.rs`):
//!
//! * **Round trip**: `decode(encode(e)) == e` for every event, including
//!   full [`StreamSpec`] / [`DeviceInstance`] payloads (f64 fields are
//!   written shortest-round-trip, so equality is exact, not approximate).
//! * **Versioning**: events carry no per-message version; the log
//!   envelope ([`crate::control::EventLog`]) stamps [`WIRE_VERSION`] and
//!   decode rejects logs from a different major format.

use std::collections::BTreeMap;
use std::fmt;

use crate::autoscale::ladder::{ModelLadder, Rung};
use crate::autoscale::policy::AutoscaleConfig;
use crate::control::plane::{ControlAction, ControlOrigin};
use crate::device::{DetectorModelId, DeviceInstance, DeviceKind};
use crate::fleet::admission::{AdmissionMode, AdmissionPolicy, Decision, DegradeMode};
use crate::fleet::stream::{RateProfile, StreamId, StreamSpec};
use crate::gate::signal::MotionDynamics;
use crate::gate::{GateConfig, GateVerdict};
use crate::util::json::Json;

/// Wire-format version stamped on every encoded event log; decode
/// rejects logs whose `format` differs.
pub const WIRE_VERSION: i64 = 1;

/// Decode failure: a structurally valid JSON document that is not a
/// valid wire event (missing field, unknown tag, wrong type).
#[derive(Debug, Clone, PartialEq)]
pub struct WireError {
    pub msg: String,
}

impl WireError {
    pub fn new(msg: impl Into<String>) -> WireError {
        WireError { msg: msg.into() }
    }

    fn missing(key: &str) -> WireError {
        WireError::new(format!("missing or mistyped field {key:?}"))
    }
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "wire decode error: {}", self.msg)
    }
}

impl std::error::Error for WireError {}

/// Payload of one wire event.
#[derive(Debug, Clone, PartialEq)]
pub enum WirePayload {
    /// A control verb (attach/detach/swap).
    Action(ControlAction),
    /// An admission outcome for stream `stream` (emitted by the
    /// wall-clock serve path and replayable for audit).
    Decision { stream: StreamId, decision: Decision },
    /// A per-frame motion-gate verdict for frame `frame` of stream
    /// `stream` (emitted by [`crate::gate`]-armed engines; steady-state
    /// `Detect` verdicts are not logged to bound wire volume).
    Gate {
        stream: StreamId,
        frame: u64,
        verdict: GateVerdict,
    },
}

/// One serialisable control-plane message.
#[derive(Debug, Clone, PartialEq)]
pub struct WireEvent {
    /// Fleet time (virtual or wall-clock seconds) the event applies at.
    pub at: f64,
    pub origin: ControlOrigin,
    pub payload: WirePayload,
}

impl WireEvent {
    /// Wrap a control action.
    pub fn action(at: f64, origin: ControlOrigin, action: ControlAction) -> WireEvent {
        WireEvent {
            at,
            origin,
            payload: WirePayload::Action(action),
        }
    }

    /// Wrap an admission decision.
    pub fn decision(at: f64, stream: StreamId, decision: Decision) -> WireEvent {
        WireEvent {
            at,
            origin: ControlOrigin::Admission,
            payload: WirePayload::Decision { stream, decision },
        }
    }

    /// Wrap a per-frame gate verdict.
    pub fn gate(at: f64, stream: StreamId, frame: u64, verdict: GateVerdict) -> WireEvent {
        WireEvent {
            at,
            origin: ControlOrigin::Gate,
            payload: WirePayload::Gate { stream, frame, verdict },
        }
    }

    /// Human label (delegates to the payload).
    pub fn label(&self) -> String {
        match &self.payload {
            WirePayload::Action(a) => a.label(),
            WirePayload::Decision { stream, decision } => {
                format!("decision(s{stream}: {})", decision.label())
            }
            WirePayload::Gate { stream, frame, verdict } => match verdict {
                GateVerdict::DownRung(r) => {
                    format!("gate(s{stream} f{frame} down-rung {r})")
                }
                v => format!("gate(s{stream} f{frame} {})", v.label()),
            },
        }
    }

    /// The wrapped action, if this event carries one.
    pub fn as_action(&self) -> Option<&ControlAction> {
        match &self.payload {
            WirePayload::Action(a) => Some(a),
            WirePayload::Decision { .. } | WirePayload::Gate { .. } => None,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("at".to_string(), Json::Num(self.at));
        o.insert(
            "origin".to_string(),
            Json::Str(self.origin.label().to_string()),
        );
        match &self.payload {
            WirePayload::Action(ControlAction::AttachStream(spec)) => {
                o.insert("type".to_string(), Json::Str("attach-stream".to_string()));
                o.insert("stream".to_string(), stream_spec_to_json(spec));
            }
            WirePayload::Action(ControlAction::DetachStream(id)) => {
                o.insert("type".to_string(), Json::Str("detach-stream".to_string()));
                o.insert("stream_id".to_string(), Json::Num(*id as f64));
            }
            WirePayload::Action(ControlAction::AttachDevice(d)) => {
                o.insert("type".to_string(), Json::Str("attach-device".to_string()));
                o.insert("device".to_string(), device_to_json(d));
            }
            WirePayload::Action(ControlAction::DetachDevice(dev)) => {
                o.insert("type".to_string(), Json::Str("detach-device".to_string()));
                o.insert("device_id".to_string(), Json::Num(*dev as f64));
            }
            WirePayload::Action(ControlAction::SwapModel { stream, rung }) => {
                o.insert("type".to_string(), Json::Str("swap-model".to_string()));
                o.insert("stream_id".to_string(), Json::Num(*stream as f64));
                o.insert("rung".to_string(), Json::Num(*rung as f64));
            }
            WirePayload::Decision { stream, decision } => {
                o.insert("type".to_string(), Json::Str("decision".to_string()));
                o.insert("stream_id".to_string(), Json::Num(*stream as f64));
                o.insert("decision".to_string(), decision_to_json(decision));
            }
            WirePayload::Gate { stream, frame, verdict } => {
                o.insert("type".to_string(), Json::Str("gate".to_string()));
                o.insert("stream_id".to_string(), Json::Num(*stream as f64));
                o.insert("frame".to_string(), Json::Num(*frame as f64));
                o.insert(
                    "verdict".to_string(),
                    Json::Str(verdict.label().to_string()),
                );
                if let GateVerdict::DownRung(r) = verdict {
                    o.insert("rung".to_string(), Json::Num(*r as f64));
                }
            }
        }
        Json::Obj(o)
    }

    pub fn from_json(v: &Json) -> Result<WireEvent, WireError> {
        let at = req_f64(v, "at")?;
        let origin = ControlOrigin::parse(req_str(v, "origin")?)
            .ok_or_else(|| WireError::new("unknown origin"))?;
        let kind = req_str(v, "type")?;
        let payload = match kind {
            "attach-stream" => {
                let spec = v.get("stream").ok_or_else(|| WireError::missing("stream"))?;
                WirePayload::Action(ControlAction::AttachStream(stream_spec_from_json(spec)?))
            }
            "detach-stream" => {
                WirePayload::Action(ControlAction::DetachStream(req_usize(v, "stream_id")?))
            }
            "attach-device" => {
                let dev = v.get("device").ok_or_else(|| WireError::missing("device"))?;
                WirePayload::Action(ControlAction::AttachDevice(device_from_json(dev)?))
            }
            "detach-device" => {
                WirePayload::Action(ControlAction::DetachDevice(req_usize(v, "device_id")?))
            }
            "swap-model" => WirePayload::Action(ControlAction::SwapModel {
                stream: req_usize(v, "stream_id")?,
                rung: req_usize(v, "rung")?,
            }),
            "decision" => {
                let d = v
                    .get("decision")
                    .ok_or_else(|| WireError::missing("decision"))?;
                WirePayload::Decision {
                    stream: req_usize(v, "stream_id")?,
                    decision: decision_from_json(d)?,
                }
            }
            "gate" => {
                let verdict = match req_str(v, "verdict")? {
                    "detect" => GateVerdict::Detect,
                    "scene-cut" => GateVerdict::SceneCut,
                    "skip-cap" => GateVerdict::SkipCap,
                    "skip" => GateVerdict::Skip,
                    "down-rung" => GateVerdict::DownRung(req_usize(v, "rung")?),
                    other => {
                        return Err(WireError::new(format!("unknown gate verdict {other:?}")))
                    }
                };
                WirePayload::Gate {
                    stream: req_usize(v, "stream_id")?,
                    frame: req_u64(v, "frame")?,
                    verdict,
                }
            }
            other => return Err(WireError::new(format!("unknown event type {other:?}"))),
        };
        Ok(WireEvent { at, origin, payload })
    }

    /// Serialise to a compact JSON string.
    pub fn encode(&self) -> String {
        self.to_json().to_string()
    }

    /// Parse a compact JSON string produced by [`WireEvent::encode`].
    pub fn decode(text: &str) -> Result<WireEvent, WireError> {
        let v = Json::parse(text).map_err(|e| WireError::new(e.to_string()))?;
        WireEvent::from_json(&v)
    }
}

// ---- field helpers -----------------------------------------------------

pub(crate) fn req_f64(v: &Json, key: &str) -> Result<f64, WireError> {
    v.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| WireError::missing(key))
}

pub(crate) fn req_u64(v: &Json, key: &str) -> Result<u64, WireError> {
    let n = req_f64(v, key)?;
    if n < 0.0 || n.fract() != 0.0 {
        return Err(WireError::new(format!(
            "field {key:?} must be a non-negative integer"
        )));
    }
    Ok(n as u64)
}

pub(crate) fn req_usize(v: &Json, key: &str) -> Result<usize, WireError> {
    Ok(req_u64(v, key)? as usize)
}

pub(crate) fn req_str<'a>(v: &'a Json, key: &str) -> Result<&'a str, WireError> {
    v.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| WireError::missing(key))
}

// ---- StreamSpec --------------------------------------------------------

pub fn stream_spec_to_json(spec: &StreamSpec) -> Json {
    let mut o = BTreeMap::new();
    o.insert("name".to_string(), Json::Str(spec.name.clone()));
    o.insert("fps".to_string(), Json::Num(spec.fps));
    o.insert("num_frames".to_string(), Json::Num(spec.num_frames as f64));
    o.insert("weight".to_string(), Json::Num(spec.weight));
    o.insert("window".to_string(), Json::Num(spec.window as f64));
    // The periodic rate profile is optional and omitted when absent, so
    // flat-stream wire text is byte-identical to pre-profile builds (and
    // pre-profile decoders, which ignore unknown keys, stay compatible).
    if let Some(p) = &spec.profile {
        let mut m = BTreeMap::new();
        m.insert("period".to_string(), Json::Num(p.period));
        m.insert(
            "mults".to_string(),
            Json::Arr(p.mults.iter().map(|&x| Json::Num(x)).collect()),
        );
        o.insert("profile".to_string(), Json::Obj(m));
    }
    Json::Obj(o)
}

/// Decode the optional periodic rate profile (absent or `null` → flat).
pub(crate) fn rate_profile_from_json(v: &Json) -> Result<Option<RateProfile>, WireError> {
    let p = match v.get("profile") {
        None | Some(Json::Null) => return Ok(None),
        Some(p) => p,
    };
    let period = req_f64(p, "period")?;
    if !period.is_finite() || period <= 0.0 {
        return Err(WireError::new("rate profile period must be positive"));
    }
    let mults = match p.get("mults") {
        Some(Json::Arr(a)) if !a.is_empty() => {
            let mut mults = Vec::with_capacity(a.len());
            for x in a {
                let m = x.as_f64().ok_or_else(|| WireError::missing("mults"))?;
                if !m.is_finite() || m <= 0.0 {
                    return Err(WireError::new("rate profile multipliers must be positive"));
                }
                mults.push(m);
            }
            mults
        }
        _ => return Err(WireError::missing("mults")),
    };
    Ok(Some(RateProfile { period, mults }))
}

pub fn stream_spec_from_json(v: &Json) -> Result<StreamSpec, WireError> {
    let fps = req_f64(v, "fps")?;
    if !fps.is_finite() || fps <= 0.0 {
        return Err(WireError::new("stream fps must be positive"));
    }
    let weight = req_f64(v, "weight")?;
    if !weight.is_finite() || weight <= 0.0 {
        return Err(WireError::new("stream weight must be positive"));
    }
    let mut spec = StreamSpec::new(req_str(v, "name")?, fps, req_u64(v, "num_frames")?);
    spec.weight = weight;
    spec.window = req_usize(v, "window")?.max(1);
    spec.profile = rate_profile_from_json(v)?;
    Ok(spec)
}

// ---- DeviceInstance ----------------------------------------------------

fn kind_code(kind: DeviceKind) -> &'static str {
    match kind {
        DeviceKind::Ncs2 => "ncs2",
        DeviceKind::FastCpu => "fast-cpu",
        DeviceKind::SlowCpu => "slow-cpu",
        DeviceKind::TitanX => "titan-x",
    }
}

fn kind_from_code(code: &str) -> Option<DeviceKind> {
    match code {
        "ncs2" => Some(DeviceKind::Ncs2),
        "fast-cpu" => Some(DeviceKind::FastCpu),
        "slow-cpu" => Some(DeviceKind::SlowCpu),
        "titan-x" => Some(DeviceKind::TitanX),
        _ => None,
    }
}

fn model_code(model: DetectorModelId) -> &'static str {
    match model {
        DetectorModelId::Ssd300 => "ssd300",
        DetectorModelId::Yolov3 => "yolov3",
    }
}

pub fn device_to_json(d: &DeviceInstance) -> Json {
    let mut o = BTreeMap::new();
    o.insert("kind".to_string(), Json::Str(kind_code(d.kind).to_string()));
    o.insert(
        "model".to_string(),
        Json::Str(model_code(d.model).to_string()),
    );
    o.insert("replica".to_string(), Json::Num(d.replica as f64));
    o.insert("jitter_cv".to_string(), Json::Num(d.jitter_cv));
    o.insert(
        "rate_override".to_string(),
        match d.rate_override {
            Some(r) => Json::Num(r),
            None => Json::Null,
        },
    );
    Json::Obj(o)
}

pub fn device_from_json(v: &Json) -> Result<DeviceInstance, WireError> {
    let kind = kind_from_code(req_str(v, "kind")?)
        .ok_or_else(|| WireError::new("unknown device kind"))?;
    let model = DetectorModelId::parse(req_str(v, "model")?)
        .ok_or_else(|| WireError::new("unknown detector model"))?;
    let mut d = DeviceInstance::new(kind, model, req_usize(v, "replica")?);
    d.jitter_cv = req_f64(v, "jitter_cv")?;
    d.rate_override = match v.get("rate_override") {
        Some(Json::Null) | None => None,
        Some(j) => Some(
            j.as_f64()
                .ok_or_else(|| WireError::missing("rate_override"))?,
        ),
    };
    Ok(d)
}

// ---- Decision ----------------------------------------------------------

pub fn decision_to_json(d: &Decision) -> Json {
    let mut o = BTreeMap::new();
    match d {
        Decision::Admit { share } => {
            o.insert("kind".to_string(), Json::Str("admit".to_string()));
            o.insert("share".to_string(), Json::Num(*share));
        }
        Decision::Degrade { stride, share } => {
            o.insert("kind".to_string(), Json::Str("degrade".to_string()));
            o.insert("stride".to_string(), Json::Num(*stride as f64));
            o.insert("share".to_string(), Json::Num(*share));
        }
        Decision::SwapModel { rung, stride, share } => {
            o.insert("kind".to_string(), Json::Str("swap".to_string()));
            o.insert("rung".to_string(), Json::Num(*rung as f64));
            o.insert("stride".to_string(), Json::Num(*stride as f64));
            o.insert("share".to_string(), Json::Num(*share));
        }
        Decision::Reject => {
            o.insert("kind".to_string(), Json::Str("reject".to_string()));
        }
    }
    Json::Obj(o)
}

pub fn decision_from_json(v: &Json) -> Result<Decision, WireError> {
    match req_str(v, "kind")? {
        "admit" => Ok(Decision::Admit {
            share: req_f64(v, "share")?,
        }),
        "degrade" => Ok(Decision::Degrade {
            stride: req_u64(v, "stride")?,
            share: req_f64(v, "share")?,
        }),
        "swap" => Ok(Decision::SwapModel {
            rung: req_usize(v, "rung")?,
            stride: req_u64(v, "stride")?,
            share: req_f64(v, "share")?,
        }),
        "reject" => Ok(Decision::Reject),
        other => Err(WireError::new(format!("unknown decision kind {other:?}"))),
    }
}

// ---- AdmissionPolicy / DegradeMode -------------------------------------

/// Serialise an admission policy (the wire format covers the whole
/// control vocabulary so a remote shard can reconstruct its admission
/// configuration, not just individual verbs).
pub fn admission_to_json(p: &AdmissionPolicy) -> Json {
    let mut o = BTreeMap::new();
    o.insert(
        "target_utilization".to_string(),
        Json::Num(p.target_utilization),
    );
    o.insert("min_rate".to_string(), Json::Num(p.min_rate));
    o.insert(
        "mode".to_string(),
        Json::Str(
            match p.mode {
                AdmissionMode::Enforce => "enforce",
                AdmissionMode::AdmitAll => "admit-all",
            }
            .to_string(),
        ),
    );
    o.insert(
        "degrade".to_string(),
        match &p.degrade {
            DegradeMode::Stride => Json::Str("stride".to_string()),
            DegradeMode::ModelSwap { speedups } => {
                Json::Arr(speedups.iter().map(|&s| Json::Num(s)).collect())
            }
        },
    );
    Json::Obj(o)
}

pub fn admission_from_json(v: &Json) -> Result<AdmissionPolicy, WireError> {
    let mode = match req_str(v, "mode")? {
        "enforce" => AdmissionMode::Enforce,
        "admit-all" => AdmissionMode::AdmitAll,
        other => return Err(WireError::new(format!("unknown admission mode {other:?}"))),
    };
    let degrade = match v.get("degrade") {
        Some(Json::Str(s)) if s == "stride" => DegradeMode::Stride,
        Some(Json::Arr(a)) => {
            let mut speedups = Vec::with_capacity(a.len());
            for x in a {
                speedups.push(x.as_f64().ok_or_else(|| WireError::missing("degrade"))?);
            }
            DegradeMode::ModelSwap { speedups }
        }
        _ => return Err(WireError::missing("degrade")),
    };
    Ok(AdmissionPolicy {
        target_utilization: req_f64(v, "target_utilization")?,
        min_rate: req_f64(v, "min_rate")?,
        mode,
        degrade,
        // Runtime burst-hold state is armed per epoch by the local
        // forecaster, never carried in the handshake.
        hold: false,
    })
}

// ---- AutoscaleConfig ---------------------------------------------------

/// Serialise a shard-local autoscale configuration. The wire format
/// covers the whole control vocabulary, and per-shard capacity control
/// ([`crate::shard::autoscale`]) is configured by the coordinator: the
/// config rides the transport handshake so a remote shard runs the
/// closed loop with exactly the coordinator's parameters. Ladders are
/// carried rung-for-rung (no re-pruning on decode) so the round trip is
/// the identity.
pub fn autoscale_config_to_json(cfg: &AutoscaleConfig) -> Json {
    let mut o = BTreeMap::new();
    o.insert("signal_window".to_string(), Json::Num(cfg.signal_window));
    o.insert("tick".to_string(), Json::Num(cfg.tick));
    o.insert("p99_bound".to_string(), Json::Num(cfg.p99_bound));
    o.insert("max_drop_rate".to_string(), Json::Num(cfg.max_drop_rate));
    o.insert("cooldown".to_string(), Json::Num(cfg.cooldown));
    o.insert("hysteresis".to_string(), Json::Num(cfg.hysteresis));
    o.insert("recovery_frac".to_string(), Json::Num(cfg.recovery_frac));
    o.insert("min_devices".to_string(), Json::Num(cfg.min_devices as f64));
    o.insert("max_devices".to_string(), Json::Num(cfg.max_devices as f64));
    o.insert(
        "device_kind".to_string(),
        Json::Str(kind_code(cfg.device_kind).to_string()),
    );
    o.insert(
        "device_model".to_string(),
        Json::Str(model_code(cfg.device_model).to_string()),
    );
    o.insert("device_rate".to_string(), Json::Num(cfg.device_rate));
    o.insert(
        "ladder".to_string(),
        match &cfg.ladder {
            None => Json::Null,
            Some(l) => Json::Arr(
                l.rungs
                    .iter()
                    .map(|r| {
                        let mut m = BTreeMap::new();
                        m.insert("name".to_string(), Json::Str(r.name.clone()));
                        m.insert("speedup".to_string(), Json::Num(r.speedup));
                        m.insert("quality".to_string(), Json::Num(r.quality));
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        },
    );
    o.insert(
        "target_utilization".to_string(),
        Json::Num(cfg.target_utilization),
    );
    Json::Obj(o)
}

pub fn autoscale_config_from_json(v: &Json) -> Result<AutoscaleConfig, WireError> {
    let ladder = match v.get("ladder") {
        Some(Json::Null) | None => None,
        Some(Json::Arr(a)) => {
            let mut rungs = Vec::with_capacity(a.len());
            for r in a {
                let speedup = req_f64(r, "speedup")?;
                if !speedup.is_finite() || speedup <= 0.0 {
                    return Err(WireError::new("ladder rung speedup must be positive"));
                }
                rungs.push(Rung {
                    name: req_str(r, "name")?.to_string(),
                    speedup,
                    quality: req_f64(r, "quality")?,
                });
            }
            Some(ModelLadder { rungs })
        }
        _ => return Err(WireError::missing("ladder")),
    };
    let device_rate = req_f64(v, "device_rate")?;
    if !device_rate.is_finite() || device_rate <= 0.0 {
        return Err(WireError::new("autoscale device_rate must be positive"));
    }
    Ok(AutoscaleConfig {
        signal_window: req_f64(v, "signal_window")?,
        tick: req_f64(v, "tick")?,
        p99_bound: req_f64(v, "p99_bound")?,
        max_drop_rate: req_f64(v, "max_drop_rate")?,
        cooldown: req_f64(v, "cooldown")?,
        hysteresis: req_f64(v, "hysteresis")?,
        recovery_frac: req_f64(v, "recovery_frac")?,
        min_devices: req_usize(v, "min_devices")?,
        max_devices: req_usize(v, "max_devices")?,
        device_kind: kind_from_code(req_str(v, "device_kind")?)
            .ok_or_else(|| WireError::new("unknown device kind"))?,
        device_model: DetectorModelId::parse(req_str(v, "device_model")?)
            .ok_or_else(|| WireError::new("unknown detector model"))?,
        device_rate,
        ladder,
        target_utilization: req_f64(v, "target_utilization")?,
    })
}

// ---- GateConfig --------------------------------------------------------

/// Serialise a per-frame gate configuration. Like the autoscale config,
/// it rides the transport handshake (the optional `gate` field of
/// `Hello`) so a coordinator can arm remote shards with exactly its own
/// gate tuning; peers that predate the gate simply omit the field.
pub fn gate_config_to_json(cfg: &GateConfig) -> Json {
    let mut o = BTreeMap::new();
    o.insert("skip_threshold".to_string(), Json::Num(cfg.skip_threshold));
    o.insert(
        "resume_threshold".to_string(),
        Json::Num(cfg.resume_threshold),
    );
    o.insert(
        "scene_cut_threshold".to_string(),
        Json::Num(cfg.scene_cut_threshold),
    );
    o.insert(
        "max_skip_run".to_string(),
        Json::Num(cfg.max_skip_run as f64),
    );
    o.insert(
        "tracker_stretch".to_string(),
        Json::Num(cfg.tracker_stretch),
    );
    o.insert(
        "pressure_threshold".to_string(),
        Json::Num(cfg.pressure_threshold),
    );
    o.insert(
        "pressure_rung".to_string(),
        Json::Num(cfg.pressure_rung as f64),
    );
    o.insert("alpha".to_string(), Json::Num(cfg.alpha));
    let mut d = BTreeMap::new();
    d.insert("base".to_string(), Json::Num(cfg.dynamics.base));
    d.insert("jitter".to_string(), Json::Num(cfg.dynamics.jitter));
    d.insert(
        "cut_every".to_string(),
        Json::Num(cfg.dynamics.cut_every as f64),
    );
    o.insert("dynamics".to_string(), Json::Obj(d));
    Json::Obj(o)
}

pub fn gate_config_from_json(v: &Json) -> Result<GateConfig, WireError> {
    let skip_threshold = req_f64(v, "skip_threshold")?;
    let resume_threshold = req_f64(v, "resume_threshold")?;
    if !skip_threshold.is_finite() || skip_threshold < 0.0 {
        return Err(WireError::new("gate skip_threshold must be >= 0"));
    }
    if !resume_threshold.is_finite() || resume_threshold < skip_threshold {
        return Err(WireError::new(
            "gate resume_threshold must be >= skip_threshold",
        ));
    }
    let scene_cut_threshold = req_f64(v, "scene_cut_threshold")?;
    if !scene_cut_threshold.is_finite() || scene_cut_threshold < 0.0 {
        return Err(WireError::new("gate scene_cut_threshold must be >= 0"));
    }
    let max_skip_run = req_u64(v, "max_skip_run")?;
    if max_skip_run < 1 {
        return Err(WireError::new("gate max_skip_run must be >= 1"));
    }
    let tracker_stretch = req_f64(v, "tracker_stretch")?;
    if !tracker_stretch.is_finite() || tracker_stretch < 1.0 {
        return Err(WireError::new("gate tracker_stretch must be >= 1"));
    }
    let alpha = req_f64(v, "alpha")?;
    if !alpha.is_finite() || alpha <= 0.0 || alpha > 1.0 {
        return Err(WireError::new("gate alpha must be in (0, 1]"));
    }
    let d = v
        .get("dynamics")
        .ok_or_else(|| WireError::missing("dynamics"))?;
    let base = req_f64(d, "base")?;
    let jitter = req_f64(d, "jitter")?;
    if !base.is_finite() || base < 0.0 || !jitter.is_finite() || jitter < 0.0 {
        return Err(WireError::new("gate dynamics must be non-negative"));
    }
    Ok(GateConfig {
        skip_threshold,
        resume_threshold,
        scene_cut_threshold,
        max_skip_run,
        tracker_stretch,
        pressure_threshold: req_f64(v, "pressure_threshold")?,
        pressure_rung: req_usize(v, "pressure_rung")?,
        alpha,
        dynamics: MotionDynamics {
            base,
            jitter,
            cut_every: req_u64(d, "cut_every")?,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(ev: &WireEvent) {
        let text = ev.encode();
        let back = WireEvent::decode(&text).expect("decode");
        assert_eq!(&back, ev, "wire text: {text}");
    }

    #[test]
    fn every_action_variant_roundtrips() {
        let spec = StreamSpec::new("cam-0", 7.25, 321).with_weight(2.5).with_window(6);
        let mut dev = DeviceInstance::new(DeviceKind::FastCpu, DetectorModelId::Ssd300, 4);
        dev.jitter_cv = 0.015;
        roundtrip(&WireEvent::action(
            0.0,
            ControlOrigin::Scripted,
            ControlAction::AttachStream(spec),
        ));
        roundtrip(&WireEvent::action(
            12.5,
            ControlOrigin::Controller,
            ControlAction::DetachStream(9),
        ));
        roundtrip(&WireEvent::action(
            3.125,
            ControlOrigin::Placement,
            ControlAction::AttachDevice(dev.clone()),
        ));
        dev.rate_override = Some(13.5);
        roundtrip(&WireEvent::action(
            4.0,
            ControlOrigin::Placement,
            ControlAction::AttachDevice(dev),
        ));
        roundtrip(&WireEvent::action(
            5.0,
            ControlOrigin::Scripted,
            ControlAction::DetachDevice(2),
        ));
        roundtrip(&WireEvent::action(
            6.0,
            ControlOrigin::Controller,
            ControlAction::SwapModel { stream: 1, rung: 2 },
        ));
    }

    #[test]
    fn every_decision_variant_roundtrips() {
        roundtrip(&WireEvent::decision(0.0, 0, Decision::Admit { share: 5.0 }));
        roundtrip(&WireEvent::decision(
            0.0,
            1,
            Decision::Degrade { stride: 3, share: 2.375 },
        ));
        roundtrip(&WireEvent::decision(
            1.5,
            2,
            Decision::SwapModel { rung: 1, stride: 2, share: 1.25 },
        ));
        roundtrip(&WireEvent::decision(2.0, 3, Decision::Reject));
    }

    #[test]
    fn profiled_stream_specs_roundtrip_and_flat_text_is_unchanged() {
        use crate::fleet::stream::RateProfile;
        let spec = StreamSpec::new("diurnal", 8.0, 160)
            .with_profile(RateProfile::new(40.0, vec![0.5, 1.0, 2.0, 1.0]));
        roundtrip(&WireEvent::action(
            0.0,
            ControlOrigin::Scripted,
            ControlAction::AttachStream(spec),
        ));
        // Flat streams omit the key entirely — legacy decoders (which
        // ignore unknown keys) and legacy text (no "profile") both work.
        let flat = stream_spec_to_json(&StreamSpec::new("flat", 8.0, 160));
        assert!(!flat.to_string().contains("profile"));
        let legacy = r#"{"name":"old","fps":5,"num_frames":10,"weight":1,"window":4}"#;
        let spec = stream_spec_from_json(&Json::parse(legacy).unwrap()).unwrap();
        assert!(spec.profile.is_none());
        // Malformed profiles are rejected, not defaulted.
        let bad = r#"{"name":"x","fps":5,"num_frames":10,"weight":1,"window":4,"profile":{"period":0,"mults":[1]}}"#;
        assert!(stream_spec_from_json(&Json::parse(bad).unwrap()).is_err());
        let bad = r#"{"name":"x","fps":5,"num_frames":10,"weight":1,"window":4,"profile":{"period":10,"mults":[]}}"#;
        assert!(stream_spec_from_json(&Json::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn fractional_f64_fields_roundtrip_exactly() {
        // Shortest-round-trip float printing means equality is exact even
        // for non-representable decimals.
        let spec = StreamSpec::new("s", 0.1 + 0.2, 10);
        let ev = WireEvent::action(
            0.30000000000000004,
            ControlOrigin::Scripted,
            ControlAction::AttachStream(spec),
        );
        roundtrip(&ev);
    }

    #[test]
    fn decode_rejects_malformed_events() {
        assert!(WireEvent::decode("not json").is_err());
        assert!(WireEvent::decode("{}").is_err());
        assert!(
            WireEvent::decode(r#"{"at":1,"origin":"scripted","type":"launch-missiles"}"#).is_err()
        );
        assert!(WireEvent::decode(r#"{"at":1,"origin":"nobody","type":"detach-stream","stream_id":0}"#).is_err());
        // Negative and fractional ids are rejected rather than wrapped
        // or truncated (1.9 must not silently detach stream 1).
        assert!(
            WireEvent::decode(r#"{"at":1,"origin":"scripted","type":"detach-stream","stream_id":-3}"#)
                .is_err()
        );
        assert!(
            WireEvent::decode(r#"{"at":1,"origin":"scripted","type":"detach-stream","stream_id":1.9}"#)
                .is_err()
        );
        // Invalid stream parameters are rejected at decode time, not at
        // the StreamSpec constructor's assert.
        assert!(WireEvent::decode(
            r#"{"at":0,"origin":"scripted","type":"attach-stream","stream":{"name":"x","fps":0,"num_frames":1,"weight":1,"window":4}}"#
        )
        .is_err());
    }

    #[test]
    fn admission_policy_roundtrips() {
        for p in [
            AdmissionPolicy::default(),
            AdmissionPolicy::admit_all(),
            AdmissionPolicy::with_ladder(vec![1.0, 2.6, 3.2]),
        ] {
            let j = admission_to_json(&p);
            let back = admission_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
            assert_eq!(back.target_utilization, p.target_utilization);
            assert_eq!(back.min_rate, p.min_rate);
            assert_eq!(back.mode, p.mode);
            assert_eq!(back.degrade, p.degrade);
        }
        assert!(admission_from_json(&Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn autoscale_config_roundtrips() {
        let plain = AutoscaleConfig::default();
        let laddered = AutoscaleConfig {
            signal_window: 2.5,
            tick: 0.5,
            cooldown: 12.5,
            min_devices: 2,
            max_devices: 9,
            device_kind: DeviceKind::FastCpu,
            device_model: DetectorModelId::Ssd300,
            device_rate: 3.75,
            target_utilization: 0.875,
            ..AutoscaleConfig::default()
        }
        .with_ladder(ModelLadder::pareto(vec![
            Rung { name: "full".into(), speedup: 1.0, quality: 0.86 },
            Rung { name: "tiny".into(), speedup: 2.6, quality: 0.69 },
        ]));
        for cfg in [plain, laddered] {
            let text = autoscale_config_to_json(&cfg).to_string();
            let back = autoscale_config_from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, cfg, "wire text: {text}");
        }
        // Missing fields and malformed ladders are rejected, not defaulted.
        assert!(autoscale_config_from_json(&Json::parse("{}").unwrap()).is_err());
        let mut j = autoscale_config_to_json(&AutoscaleConfig::default());
        if let Json::Obj(o) = &mut j {
            o.insert("ladder".to_string(), Json::Str("oops".to_string()));
        }
        assert!(autoscale_config_from_json(&j).is_err());
    }

    #[test]
    fn random_autoscale_configs_survive_the_codec() {
        use crate::util::prop::{check, Config};
        check("autoscale config roundtrip", Config::default(), |rng| {
            let ladder = if rng.chance(0.5) {
                let n = rng.int_in(1, 4) as usize;
                Some(ModelLadder {
                    rungs: (0..n)
                        .map(|i| Rung {
                            name: format!("rung-{i}"),
                            speedup: rng.range(0.5, 8.0),
                            quality: rng.range(0.05, 0.95),
                        })
                        .collect(),
                })
            } else {
                None
            };
            let cfg = AutoscaleConfig {
                signal_window: rng.range(0.5, 16.0),
                tick: rng.range(0.1, 4.0),
                p99_bound: rng.range(0.2, 5.0),
                max_drop_rate: rng.range(0.0, 0.5),
                cooldown: rng.range(0.5, 30.0),
                hysteresis: rng.range(1.0, 2.0),
                recovery_frac: rng.range(0.1, 0.9),
                min_devices: rng.int_in(0, 4) as usize,
                max_devices: rng.int_in(4, 64) as usize,
                device_kind: *rng.choose(&[
                    DeviceKind::Ncs2,
                    DeviceKind::FastCpu,
                    DeviceKind::SlowCpu,
                    DeviceKind::TitanX,
                ]),
                device_model: *rng.choose(&[
                    DetectorModelId::Ssd300,
                    DetectorModelId::Yolov3,
                ]),
                device_rate: rng.range(0.5, 40.0),
                ladder,
                target_utilization: rng.range(0.5, 1.0),
            };
            let text = autoscale_config_to_json(&cfg).to_string();
            let parsed = Json::parse(&text).map_err(|e| e.to_string())?;
            let back = autoscale_config_from_json(&parsed).map_err(|e| e.to_string())?;
            if back != cfg {
                return Err(format!("decoded {back:?} != original {cfg:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn labels_cover_payloads() {
        let ev = WireEvent::decision(0.0, 4, Decision::Reject);
        assert_eq!(ev.label(), "decision(s4: reject)");
        assert!(ev.as_action().is_none());
        let ev = WireEvent::action(0.0, ControlOrigin::Scripted, ControlAction::DetachDevice(0));
        assert_eq!(ev.label(), "detach-device(#0)");
        assert!(ev.as_action().is_some());
        let ev = WireEvent::gate(1.5, 0, 12, GateVerdict::Skip);
        assert_eq!(ev.label(), "gate(s0 f12 skip)");
        assert_eq!(ev.origin, ControlOrigin::Gate);
        assert!(ev.as_action().is_none());
        let ev = WireEvent::gate(2.0, 1, 30, GateVerdict::DownRung(2));
        assert_eq!(ev.label(), "gate(s1 f30 down-rung 2)");
    }

    #[test]
    fn every_gate_verdict_roundtrips() {
        for verdict in [
            GateVerdict::Detect,
            GateVerdict::SceneCut,
            GateVerdict::SkipCap,
            GateVerdict::Skip,
            GateVerdict::DownRung(1),
            GateVerdict::DownRung(3),
        ] {
            roundtrip(&WireEvent::gate(2.75, 3, 41, verdict));
        }
    }

    #[test]
    fn decode_rejects_malformed_gate_events() {
        // Unknown verdicts and a down-rung without its rung are errors,
        // not defaults.
        assert!(WireEvent::decode(
            r#"{"at":1,"origin":"gate","type":"gate","stream_id":0,"frame":5,"verdict":"teleport"}"#
        )
        .is_err());
        assert!(WireEvent::decode(
            r#"{"at":1,"origin":"gate","type":"gate","stream_id":0,"frame":5,"verdict":"down-rung"}"#
        )
        .is_err());
        assert!(WireEvent::decode(
            r#"{"at":1,"origin":"gate","type":"gate","stream_id":0,"verdict":"skip"}"#
        )
        .is_err());
        assert!(WireEvent::decode(
            r#"{"at":1,"origin":"gate","type":"gate","stream_id":0,"frame":-2,"verdict":"skip"}"#
        )
        .is_err());
    }

    #[test]
    fn gate_config_roundtrips() {
        for cfg in [
            GateConfig::default(),
            GateConfig {
                skip_threshold: 0.03,
                resume_threshold: 0.11,
                scene_cut_threshold: 0.625,
                max_skip_run: 5,
                tracker_stretch: 3.5,
                pressure_threshold: 0.5,
                pressure_rung: 2,
                alpha: 0.25,
                dynamics: MotionDynamics::sports(),
            },
        ] {
            let text = gate_config_to_json(&cfg).to_string();
            let back = gate_config_from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, cfg, "wire text: {text}");
        }
        assert!(gate_config_from_json(&Json::parse("{}").unwrap()).is_err());
        // Broken hysteresis (resume below skip) is rejected at decode
        // time, not at the GatePolicy constructor's assert.
        let mut j = gate_config_to_json(&GateConfig::default());
        if let Json::Obj(o) = &mut j {
            o.insert("resume_threshold".to_string(), Json::Num(0.001));
        }
        assert!(gate_config_from_json(&j).is_err());
        let mut j = gate_config_to_json(&GateConfig::default());
        if let Json::Obj(o) = &mut j {
            o.insert("alpha".to_string(), Json::Num(0.0));
        }
        assert!(gate_config_from_json(&j).is_err());
    }

    #[test]
    fn random_gate_events_survive_the_codec() {
        use crate::util::prop::{check, Config};
        check("gate wire event roundtrip", Config::default(), |rng| {
            let verdict = match rng.below(5) {
                0 => GateVerdict::Detect,
                1 => GateVerdict::SceneCut,
                2 => GateVerdict::SkipCap,
                3 => GateVerdict::Skip,
                _ => GateVerdict::DownRung(rng.int_in(1, 6) as usize),
            };
            let ev = WireEvent::gate(
                rng.range(0.0, 1_000.0),
                rng.below(64) as usize,
                rng.next_u64() % 100_000,
                verdict,
            );
            let back = WireEvent::decode(&ev.encode()).map_err(|e| e.to_string())?;
            if back != ev {
                return Err(format!("decoded {back:?} != original {ev:?}"));
            }
            Ok(())
        });
    }
}
