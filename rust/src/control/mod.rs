//! The serialisable control plane.
//!
//! One vocabulary for everything that steers a running fleet —
//! membership changes, model swaps, admission outcomes — with three
//! faces:
//!
//! * [`plane`] — the in-memory types: [`ControlAction`] /
//!   [`ControlEvent`] (the verbs the engines apply), [`ControlOrigin`]
//!   (who issued an action) and [`ControlRecord`] (an applied action in
//!   a run log). These used to live privately inside `fleet::registry`
//!   and `fleet::sim`; they moved here so every layer — scripted
//!   scenarios, the autoscale controller, the shard placement layer —
//!   speaks the same types.
//! * [`wire`] — the versioned JSON codec: [`WireEvent`] wraps an action
//!   or an admission [`crate::fleet::admission::Decision`] with its time
//!   and origin, and round-trips exactly through
//!   [`crate::util::json::Json`]. This is what crosses a process
//!   boundary.
//! * [`log`] — [`EventLog`], the versioned, replayable event log: the
//!   audit trail of a run, decodable back into scripted events that
//!   reproduce its control plane verbatim.
//! * [`binary`] — the compact binary codec for hot-path frames (varint
//!   ints, interned strings, adaptive f32/f64 rates) behind
//!   [`crate::transport::frame::FRAME_VERSION_BINARY`]. JSON remains
//!   the audit/debug format; binary decodes to the identical
//!   [`WireEvent`] the JSON path produces, so the [`EventLog`] replay
//!   contract survives the swap bit for bit.
//! * [`caps`] — [`SessionCaps`], the versioned session-capability set
//!   the transport handshake carries (autoscale / gate / telemetry /
//!   auth token) under one forward-compatibility contract: unknown
//!   fields tolerated, absent fields defaulted, any version number
//!   accepted. It replaced the flat optional-field sprawl PRs 5–7 grew
//!   on `Hello`; the JSON handshake still writes the legacy keys so
//!   old peers interoperate.

pub mod binary;
pub mod caps;
pub mod log;
pub mod plane;
pub mod wire;

pub use caps::{SessionCaps, CAPS_VERSION};
pub use log::EventLog;
pub use plane::{ControlAction, ControlEvent, ControlOrigin, ControlRecord};
pub use wire::{
    admission_from_json, admission_to_json, decision_from_json, decision_to_json,
    device_from_json, device_to_json, gate_config_from_json, gate_config_to_json,
    stream_spec_from_json, stream_spec_to_json, WireError, WireEvent, WirePayload, WIRE_VERSION,
};
