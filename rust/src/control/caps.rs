//! Versioned session capabilities carried on the transport handshake.
//!
//! PRs 5–7 grew the `Hello` message one optional field at a time —
//! `autoscale`, then `gate`, then `telemetry` — each hand-threading its
//! own absent-means-off rule through the JSON and binary codecs. That
//! sprawl made version-skew tolerance accidental: every new capability
//! re-derived the compatibility story from scratch. [`SessionCaps`]
//! collapses them into one struct with one explicit contract:
//!
//! * **absent fields default** — a capability a peer does not mention is
//!   off (`None` / `false`), exactly as if the field were never invented;
//! * **unknown fields are tolerated** — a decoder ignores keys it does
//!   not know, so a newer peer can add capabilities without breaking an
//!   older one;
//! * **any version value is tolerated** — [`CAPS_VERSION`] stamps what
//!   this build speaks, but decode never rejects a different number; the
//!   field exists so peers can *report* skew, not refuse it.
//!
//! The struct rides the wire as one JSON object in *both* codecs — the
//! binary `Hello` embeds the same rendering — so there is exactly one
//! compatibility surface to test. Legacy peers are bridged in
//! [`crate::transport::msg`]: a new `Hello` still writes the flat
//! PR 5/6/7-era keys (which old decoders read and new decoders fall back
//! to), and [`SessionCaps::from_legacy`] lifts them when the `caps`
//! object is absent.
//!
//! `token` is the shared-secret session auth introduced with the
//! multi-machine deploy layer: a listening shard configured with a token
//! rejects a handshake that does not present the same one (a typed
//! [`crate::transport::TransportMsg::Reject`] frame, never a hang). It
//! intentionally has *no* flat legacy key — pre-auth peers cannot
//! present a token, and against a token-requiring server they are
//! rejected exactly like a missing one.

use std::collections::BTreeMap;

use crate::autoscale::policy::AutoscaleConfig;
use crate::control::wire::{
    autoscale_config_from_json, autoscale_config_to_json, gate_config_from_json,
    gate_config_to_json,
};
use crate::control::WireError;
use crate::forecast::{forecast_config_from_json, forecast_config_to_json, ForecastConfig};
use crate::gate::GateConfig;
use crate::util::json::Json;

/// The capability-schema version this build writes. Decode tolerates
/// any value — see the module contract.
pub const CAPS_VERSION: u64 = 1;

/// Everything a coordinator asks of a shard session beyond the
/// admission policy and roster: optional capability configs plus the
/// session auth token.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionCaps {
    /// Schema version stamp ([`CAPS_VERSION`]); informational on decode.
    pub version: u64,
    /// Shard-local autoscaling for the session
    /// ([`crate::shard::autoscale`]); `None` = serve the static pool.
    pub autoscale: Option<AutoscaleConfig>,
    /// Per-frame motion gating ([`crate::gate`]); `None` = detect every
    /// frame.
    pub gate: Option<GateConfig>,
    /// Ship a telemetry snapshot ahead of every epoch slice.
    pub telemetry: bool,
    /// Shared-secret session auth; must match the token the listening
    /// shard was started with (when it requires one).
    pub token: Option<String>,
    /// Per-stream arrival forecasting ([`crate::forecast`]); the shard
    /// publishes its predicted Σλ in every gossip digest and fuses the
    /// prediction into its autoscaler and admission hold. `None` = run
    /// purely reactive control (and publish no forecast slot).
    pub forecast: Option<ForecastConfig>,
}

impl Default for SessionCaps {
    fn default() -> SessionCaps {
        SessionCaps {
            version: CAPS_VERSION,
            autoscale: None,
            gate: None,
            telemetry: false,
            token: None,
            forecast: None,
        }
    }
}

impl SessionCaps {
    /// Lift the flat PR 5/6/7-era `Hello` fields into the unified
    /// struct (the decode fallback when no `caps` object rides the
    /// handshake).
    pub fn from_legacy(
        autoscale: Option<AutoscaleConfig>,
        gate: Option<GateConfig>,
        telemetry: bool,
    ) -> SessionCaps {
        SessionCaps {
            autoscale,
            gate,
            telemetry,
            ..SessionCaps::default()
        }
    }

    /// True when every capability is at its default (nothing asked of
    /// the peer beyond the base session).
    pub fn is_default(&self) -> bool {
        self.autoscale.is_none()
            && self.gate.is_none()
            && !self.telemetry
            && self.token.is_none()
            && self.forecast.is_none()
    }

    /// Consuming setter for the auth token.
    pub fn with_token(mut self, token: &str) -> SessionCaps {
        self.token = Some(token.to_string());
        self
    }

    /// One JSON rendering for both codecs. Fields at their default are
    /// omitted, so a caps object never mentions a capability the sender
    /// does not use.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("version".to_string(), Json::Num(self.version as f64));
        if let Some(cfg) = &self.autoscale {
            o.insert("autoscale".to_string(), autoscale_config_to_json(cfg));
        }
        if let Some(cfg) = &self.gate {
            o.insert("gate".to_string(), gate_config_to_json(cfg));
        }
        if self.telemetry {
            o.insert("telemetry".to_string(), Json::Bool(true));
        }
        if let Some(token) = &self.token {
            o.insert("token".to_string(), Json::Str(token.clone()));
        }
        if let Some(cfg) = &self.forecast {
            o.insert("forecast".to_string(), forecast_config_to_json(cfg));
        }
        Json::Obj(o)
    }

    /// Decode under the forward-compatibility contract: unknown keys
    /// ignored, absent or null known keys defaulted, any version number
    /// tolerated. A *malformed* known field (wrong type) is still an
    /// error — skew is tolerated, corruption is not.
    pub fn from_json(v: &Json) -> Result<SessionCaps, WireError> {
        let version = match v.get("version") {
            None | Some(Json::Null) => CAPS_VERSION,
            Some(j) => j
                .as_f64()
                .ok_or_else(|| WireError::new("caps version must be a number"))?
                as u64,
        };
        let autoscale = match v.get("autoscale") {
            None | Some(Json::Null) => None,
            Some(j) => Some(autoscale_config_from_json(j)?),
        };
        let gate = match v.get("gate") {
            None | Some(Json::Null) => None,
            Some(j) => Some(gate_config_from_json(j)?),
        };
        let telemetry = match v.get("telemetry") {
            None | Some(Json::Null) => false,
            Some(j) => j
                .as_bool()
                .ok_or_else(|| WireError::new("caps telemetry must be a bool"))?,
        };
        let token = match v.get("token") {
            None | Some(Json::Null) => None,
            Some(j) => Some(
                j.as_str()
                    .ok_or_else(|| WireError::new("caps token must be a string"))?
                    .to_string(),
            ),
        };
        let forecast = match v.get("forecast") {
            None | Some(Json::Null) => None,
            Some(j) => Some(forecast_config_from_json(j)?),
        };
        Ok(SessionCaps {
            version,
            autoscale,
            gate,
            telemetry,
            token,
            forecast,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_caps_render_to_a_bare_version_stamp() {
        let caps = SessionCaps::default();
        assert!(caps.is_default());
        let text = caps.to_json().to_string();
        assert_eq!(text, r#"{"version":1}"#);
        assert_eq!(SessionCaps::from_json(&Json::parse(&text).unwrap()).unwrap(), caps);
    }

    #[test]
    fn every_field_roundtrips() {
        let caps = SessionCaps {
            autoscale: Some(AutoscaleConfig {
                max_devices: 9,
                device_rate: 3.25,
                ..AutoscaleConfig::default()
            }),
            gate: Some(GateConfig {
                max_skip_run: 4,
                tracker_stretch: 2.5,
                ..GateConfig::default()
            }),
            telemetry: true,
            token: Some("s3cret".to_string()),
            forecast: Some(ForecastConfig {
                period: 24,
                band: 0.15,
                ..ForecastConfig::default()
            }),
            ..SessionCaps::default()
        };
        assert!(!caps.is_default());
        let v = caps.to_json();
        assert_eq!(SessionCaps::from_json(&v).unwrap(), caps);
    }

    #[test]
    fn unknown_fields_and_future_versions_are_tolerated() {
        // A "future" peer: higher version, a capability this build has
        // never heard of. Decode keeps what it knows, ignores the rest.
        let text = r#"{"version":99,"telemetry":true,"holograms":{"depth":3},"token":"t"}"#;
        let caps = SessionCaps::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(caps.version, 99);
        assert!(caps.telemetry);
        assert_eq!(caps.token.as_deref(), Some("t"));
        assert!(caps.autoscale.is_none());
        // An empty object is all defaults — absent fields never reject.
        let empty = SessionCaps::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(empty, SessionCaps::default());
    }

    #[test]
    fn malformed_known_fields_are_errors_not_defaults() {
        for text in [
            r#"{"version":"one"}"#,
            r#"{"telemetry":3}"#,
            r#"{"token":17}"#,
            r#"{"autoscale":"yes"}"#,
            r#"{"forecast":"tight"}"#,
        ] {
            assert!(
                SessionCaps::from_json(&Json::parse(text).unwrap()).is_err(),
                "accepted corrupt caps: {text}"
            );
        }
    }

    #[test]
    fn legacy_lift_matches_field_by_field() {
        let caps = SessionCaps::from_legacy(None, Some(GateConfig::default()), true);
        assert_eq!(caps.version, CAPS_VERSION);
        assert!(caps.autoscale.is_none());
        assert!(caps.gate.is_some());
        assert!(caps.telemetry);
        assert!(caps.token.is_none(), "legacy peers cannot present a token");
        let with = SessionCaps::default().with_token("k");
        assert_eq!(with.token.as_deref(), Some("k"));
    }
}
