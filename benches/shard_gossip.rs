//! Shard bench: sharded-vs-single parity shape, shard-loss recovery
//! shape, and the wire-format + gossip-round costs.
//!
//! Asserts the acceptance shapes (a 2-shard balanced split delivers
//! within 5% of the single pool at equal capacity; every orphan of a
//! lost shard is re-placed within one gossip interval), then measures
//! what the control plane costs: WireEvent encode→decode round trips
//! and one full sharded co-simulation.

use eva::control::{ControlAction, ControlOrigin, WireEvent};
use eva::experiments::shard::{autoscale_overload, balanced_split, shard_failure};
use eva::fleet::StreamSpec;
use eva::util::benchkit::{black_box, Bench};

fn main() {
    let mut bench = Bench::new(1, 3);

    let (table, outcomes) = balanced_split(29);
    print!("{}", table.render());
    let single = &outcomes[0];
    for o in &outcomes[1..] {
        let ratio = o.delivered_fps / single.delivered_fps;
        assert!(
            (ratio - 1.0).abs() < 0.05,
            "{}: σ {:.2} vs single {:.2} (ratio {ratio:.3})",
            o.label,
            o.delivered_fps,
            single.delivered_fps
        );
    }
    println!("shape OK: sharding at equal capacity is within 5% of the single pool");

    let (failure_table, failure) = shard_failure(31);
    print!("{}", failure_table.render());
    assert_eq!(failure.orphans, 3, "{failure:?}");
    assert!(
        failure.replaced_within_interval,
        "orphans must be re-placed within one gossip interval: {failure:?}"
    );
    println!("shape OK: shard-loss orphans re-placed within one gossip interval");

    let (overload_table, migrate_only, autoscaled) = autoscale_overload(41);
    print!("{}", overload_table.render());
    assert!(
        autoscaled.migrations < migrate_only.migrations,
        "local scaling must cut migrations: {} vs {}",
        autoscaled.migrations,
        migrate_only.migrations
    );
    assert!(autoscaled.scale_actions >= 1 && autoscaled.audit_clean, "{autoscaled:?}");
    println!("shape OK: per-shard autoscale cuts migrations at 2x load, audit log clean");

    // Control-plane wire cost: encode + decode one attach event (the
    // largest payload) per iteration batch.
    let spec = StreamSpec::new("bench-stream", 12.5, 3_000).with_window(8);
    bench.run("wire: encode+decode 1k attach-stream events", Some(1000.0), || {
        let mut bytes = 0usize;
        for i in 0..1000u64 {
            let ev = WireEvent::action(
                i as f64,
                ControlOrigin::Placement,
                ControlAction::AttachStream(spec.clone()),
            );
            let text = ev.encode();
            bytes += text.len();
            let back = WireEvent::decode(&text).expect("round-trip");
            black_box(back);
        }
        bytes as u64
    });

    // One full 2-shard co-simulation (what every sweep cell pays).
    bench.run("shard sim: 8 streams × 2 shards (300 frames)", Some(8.0 * 300.0), || {
        let (_, outcomes) = balanced_split(37);
        black_box(outcomes[1].delivered_fps.to_bits())
    });

    // The closed-loop variant: every epoch slice also runs the shard's
    // AutoscaleController through the FleetController seam — this is
    // what a sharded-autoscale sweep cell pays over the plain co-sim.
    bench.run(
        "shard sim: autoscale overload co-sim (2 runs)",
        Some(2.0 * (4.0 * 285.0 + 4.0 * 30.0)),
        || {
            let (_, mo, aut) = autoscale_overload(53);
            black_box(((mo.migrations as u64) << 32) | aut.scale_actions as u64)
        },
    );
}
