//! Telemetry overhead bench: what span tracing and the metrics
//! registry cost, with the observer contract asserted.
//!
//! Shape: the p99 stage budget partitions end-to-end latency within 1%
//! at every load point; tracing never changes virtual-time outputs
//! (makespan and totals are bit-identical traced vs untraced); the
//! wall-clock delta is measured and reported, not pinned — it is
//! host-dependent, and the virtual-time pin is the contract.

use eva::experiments::telemetry::{overload_sweep, sweep_scenario, tracing_overhead};
use eva::fleet::run_fleet_with;
use eva::telemetry::{MetricKey, Registry};
use eva::util::benchkit::Bench;

fn main() {
    let mut bench = Bench::new(1, 3);

    let (table, points) = overload_sweep(29);
    print!("{}", table.render());
    for p in &points {
        assert!(
            p.residue < 0.01,
            "stage budget must partition p99 within 1%: load {} residue {:.4}",
            p.load,
            p.residue
        );
    }
    println!("shape OK: stage budgets partition p99 within 1% at every load point");

    let (_, overhead) = tracing_overhead(29);
    assert!(
        overhead.virtual_identical,
        "tracing must not perturb virtual-time outputs"
    );
    println!(
        "shape OK: virtual-time outputs identical; wall overhead {:.2}% over {} frames",
        overhead.wall_overhead * 100.0,
        overhead.frames,
    );

    // Wall-clock cost of the traced vs untraced overload run (the pair
    // `tracing_overhead` times internally, here under benchkit).
    let frames = overhead.frames as f64;
    let mut untraced = sweep_scenario(33, 2.0);
    untraced.telemetry = false;
    bench.run("fleet overload run, untraced", Some(frames), || {
        run_fleet_with(&untraced, None).report.total_processed()
    });
    let traced = sweep_scenario(33, 2.0);
    bench.run("fleet overload run, traced", Some(frames), || {
        run_fleet_with(&traced, None).report.total_processed()
    });

    // Registry hot path: one labelled counter bump + one histogram
    // observation per "frame" — the per-frame cost every traced engine
    // pays.
    bench.run("registry inc+observe x 10k", Some(10_000.0), || {
        let mut reg = Registry::new();
        for i in 0..10_000u64 {
            reg.inc(
                MetricKey::with_labels("eva_frames_total", &[("stream", "s0")]),
                1,
            );
            reg.observe(
                MetricKey::with_labels("eva_stage_seconds", &[("stage", "detect")]),
                (i % 97) as f64 * 1e-4,
            );
        }
        reg.counter_family_total("eva_frames_total")
    });
}
