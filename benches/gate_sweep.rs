//! Gate sweep bench: the content-dynamics presets, gated vs
//! always-detect, with the acceptance shape asserted.
//!
//! Shape: on the low-motion lobby preset the gate buys at least 2×
//! effective per-device FPS at under 2% delivered-mAP cost; sustained
//! motion (highway) is never skipped; sports scene cuts always force a
//! fresh detection.

use eva::experiments::gate::content_sweep;
use eva::util::benchkit::Bench;

fn main() {
    let mut bench = Bench::new(1, 3);

    let (table, outcomes) = content_sweep(29);
    print!("{}", table.render());
    let cell = |preset: &str, mode: &str| {
        outcomes
            .iter()
            .find(|o| o.preset == preset && o.mode == mode)
            .unwrap_or_else(|| panic!("missing sweep cell {preset}/{mode}"))
    };

    let plain = cell("lobby", "always-detect");
    let gated = cell("lobby", "gated");
    let gain = gated.effective_device_fps / plain.effective_device_fps;
    assert!(
        gain >= 2.0,
        "lobby gate must at least double effective device FPS: {:.1} -> {:.1} ({gain:.2}x)",
        plain.effective_device_fps,
        gated.effective_device_fps
    );
    let cost = (plain.delivered_map - gated.delivered_map) / plain.delivered_map;
    assert!(
        cost < 0.02,
        "lobby mAP cost must stay under 2%: {:.2}% (gated {:.4} vs plain {:.4})",
        cost * 100.0,
        gated.delivered_map,
        plain.delivered_map
    );
    println!(
        "shape OK: lobby gate {gain:.2}x effective device FPS at {:.2}% delivered-mAP cost",
        cost * 100.0
    );

    let highway = cell("highway", "gated");
    assert_eq!(
        highway.skips, 0,
        "sustained motion must never be skipped: {highway:?}"
    );
    let sports = cell("sports", "gated");
    assert!(
        sports.refreshes >= 1,
        "sports scene cuts must force fresh detections: {sports:?}"
    );
    println!("shape OK: highway never skips; sports cuts force refreshes");

    // Wall-clock cost of the full sweep (what CI pays for BENCH_gate).
    bench.run("gate content sweep (3 presets x 2 modes)", Some(3100.0), || {
        content_sweep(33).1.len() as u64
    });
}
