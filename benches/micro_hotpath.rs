//! Micro-benchmarks of the L3 hot paths: DES event loop, scheduler
//! dispatch, sequence synchronizer, NMS, mAP evaluation, clip generation.
//! These feed EXPERIMENTS.md §Perf (before/after iteration log).

use eva::coordinator::source::FrameWindow;
use eva::coordinator::sync::{Fate, Synchronizer};
use eva::coordinator::{run_online, RunConfig, SchedulerKind, SourceMode};
use eva::device::link::LinkProfile;
use eva::device::{DetectorModelId, Fleet};
use eva::eval::{evaluate_map, nms};
use eva::experiments::common::quality_detectors;
use eva::types::{BBox, Detection, GtBox};
use eva::util::benchkit::{black_box, Bench};
use eva::util::Rng;
use eva::video::{generate, presets};

fn random_dets(rng: &mut Rng, n: usize) -> Vec<Detection> {
    (0..n)
        .map(|_| Detection {
            bbox: BBox::new(rng.f32(), rng.f32(), 0.05 + 0.2 * rng.f32(), 0.05 + 0.2 * rng.f32()),
            class_id: rng.below(3) as usize,
            score: rng.f32(),
        })
        .collect()
}

fn main() {
    let mut b = Bench::standard();

    // Full online DES run (the unit of every table cell).
    let clip = generate(&presets::eth_sunnyday(1), None);
    let fleet = Fleet::ncs2_sticks(7, DetectorModelId::Yolov3, LinkProfile::usb3());
    b.run("des: online run (354 frames, 7 devices)", Some(354.0), || {
        let cfg = RunConfig::new(SchedulerKind::Fcfs, SourceMode::Paced, 3);
        run_online(&clip, &fleet, quality_detectors(&fleet, "eth_sunnyday", 4), &cfg)
            .metrics
            .frames_processed
    });

    // Synchronizer under heavy reorder.
    b.run("sync: 10k frames, reversed completion", Some(10_000.0), || {
        let mut s = Synchronizer::new();
        let mut emitted = 0usize;
        for chunk in (0..10_000u64).collect::<Vec<_>>().chunks(50) {
            for &fid in chunk.iter().rev() {
                emitted += s
                    .resolve(fid, Fate::Processed { detections: vec![], device: 0 }, fid as f64, |f| f as f64)
                    .len();
            }
        }
        emitted
    });

    // Frame window arrive/pull cycle.
    b.run("window: 100k arrive+pull", Some(100_000.0), || {
        let mut w = FrameWindow::new(8);
        let mut pulled = 0usize;
        for f in 0..100_000u64 {
            w.arrive(f);
            if f % 2 == 0 {
                pulled += usize::from(w.pull().is_some());
            }
        }
        pulled
    });

    // NMS on realistic candidate sets.
    let mut rng = Rng::new(9);
    let dets100: Vec<Detection> = random_dets(&mut rng, 100);
    b.run("nms: 100 candidates", Some(100.0), || {
        nms(black_box(dets100.clone()), 0.45).len()
    });
    let dets1k: Vec<Detection> = random_dets(&mut rng, 1000);
    b.run("nms: 1000 candidates", Some(1000.0), || {
        nms(black_box(dets1k.clone()), 0.45).len()
    });

    // mAP evaluation over a full clip's worth of frames.
    let gts: Vec<Vec<GtBox>> = (0..525)
        .map(|_| {
            (0..5)
                .map(|i| GtBox {
                    bbox: BBox::new(rng.f32(), rng.f32(), 0.1, 0.2),
                    class_id: i % 3,
                    track_id: i as u32,
                })
                .collect()
        })
        .collect();
    let dets: Vec<Vec<Detection>> = gts
        .iter()
        .map(|g| {
            g.iter()
                .map(|gt| Detection { bbox: gt.bbox, class_id: gt.class_id, score: rng.f32() })
                .collect()
        })
        .collect();
    let gt_refs: Vec<&[GtBox]> = gts.iter().map(|g| g.as_slice()).collect();
    b.run("map: 525 frames x 5 objects", Some(525.0), || {
        evaluate_map(&dets, &gt_refs, 3, 0.5).map
    });

    // Clip generation (metadata only vs rastered).
    b.run("video: generate ETH clip (metadata)", Some(354.0), || {
        generate(&presets::eth_sunnyday(5), None).len()
    });
    b.run("video: generate 96px clip (rastered, 60f)", Some(60.0), || {
        generate(&presets::tiny_clip(96, 60, 10.0, 5), Some(96)).len()
    });
}
