//! Regenerates the configuration tables: I (videos), II (models),
//! III (edge servers) and VIII (link bandwidths), asserting the paper's
//! constants survive in the registries.

use eva::experiments::configs;

fn main() {
    let t1 = configs::table1();
    print!("{}", t1.render());
    let r1 = t1.render();
    assert!(r1.contains("525") && r1.contains("354"));
    assert!(r1.contains("1920x1080") && r1.contains("640x480"));

    let t2 = configs::table2();
    print!("{}", t2.render());
    let r2 = t2.render();
    assert!(r2.contains("300x300x3") && r2.contains("416x416x3"));
    assert!(r2.contains("51MB") && r2.contains("119MB"));

    if let Some(t) = configs::table2_tinydet(std::path::Path::new("artifacts")) {
        print!("{}", t.render());
    } else {
        println!("(TinyDet manifest not built; run `make artifacts`)");
    }

    let t3 = configs::table3();
    print!("{}", t3.render());

    let t8 = configs::table8();
    print!("{}", t8.render());
    let r8 = t8.render();
    for link in ["USB 2.0", "USB 3.0", "10 Gigabit Ethernet", "WiFi 6", "4G", "5G"] {
        assert!(r8.contains(link), "{link}");
    }
    println!("config tables OK");
}
