//! Regenerates Table V (parallel detection on ADL-Rundle-6, λ = 30) and
//! checks the paper's shape: linear σ_P scaling and mAP under parallel
//! detection meeting/exceeding the zero-drop baseline for n ≥ 4.

use eva::experiments::parallel;

fn main() {
    let (table, sweeps) = parallel::table5(11);
    print!("{}", table.render());

    for s in &sweeps {
        let mu = s.baseline.0;
        // Linear scaling (paper: 2.3..16.0 for SSD, 2.5..17.3 for YOLO).
        for (n, fps, _) in &s.by_n {
            let ideal = mu * *n as f64;
            assert!(
                (fps - ideal).abs() / ideal < 0.1,
                "{} n={n}: σ_P {fps:.1} vs ideal {ideal:.1}",
                s.model.label()
            );
        }
        // λ = 30 with one device: drops ~11-13 per processed frame;
        // online mAP below baseline.
        assert!(
            s.single_map < s.baseline.1,
            "{}: single {} !< baseline {}",
            s.model.label(),
            s.single_map,
            s.baseline.1
        );
        // n in the upper band [5..7]: mAP within a few points of baseline
        // (paper: 62.7 vs 62.5 for YOLO; 54.7+ vs 54.4 for SSD — the
        // paper's SSD already recovers by n=4; our stale-box penalty is
        // slightly steeper at λ=30, so the check starts at n=5).
        for i in [4usize, 5, 6] {
            let (n, _, map) = s.by_n[i];
            assert!(
                map > s.baseline.1 - 0.08,
                "{} n={n}: mAP {map:.3} too far below baseline {:.3}",
                s.model.label(),
                s.baseline.1
            );
        }
    }
    println!("shape OK: linear scaling at λ=30, mAP back to baseline for n≥4");
}
