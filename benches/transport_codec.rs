//! Transport bench: frame-codec cost and the socket co-simulation's
//! parity shape.
//!
//! Asserts the acceptance shapes first (split frames reassemble exactly;
//! a 2-shard loopback TCP run matches the in-process co-simulation
//! within 5%), then measures what the cross-host seam costs: whole
//! frames encoded+decoded per second, the same through a split-read
//! decoder (the worst case a stream socket produces), and one full
//! remote co-simulation against its in-process twin.

use eva::control::{ControlAction, ControlOrigin, WireEvent};
use eva::experiments::transport::loopback_parity;
use eva::fleet::StreamSpec;
use eva::transport::{encode_frame, FrameDecoder, TransportMsg};
use eva::util::benchkit::{black_box, Bench};

fn attach_msg(i: u64) -> TransportMsg {
    TransportMsg::Control(WireEvent::action(
        i as f64,
        ControlOrigin::Placement,
        ControlAction::AttachStream(
            StreamSpec::new(&format!("bench-stream-{i}"), 12.5, 3_000).with_window(8),
        ),
    ))
}

fn main() {
    let mut bench = Bench::new(1, 3);

    // Shape: a frame split across pathological read sizes reassembles
    // into exactly the encoded message sequence.
    let msgs: Vec<TransportMsg> = (0..5).map(attach_msg).collect();
    let mut stream = Vec::new();
    for m in &msgs {
        stream.extend_from_slice(&encode_frame(m).expect("encode"));
    }
    let mut dec = FrameDecoder::new();
    let mut out = Vec::new();
    for chunk in stream.chunks(7) {
        dec.feed(chunk);
        while let Some(m) = dec.try_next().expect("decode") {
            out.push(m);
        }
    }
    assert_eq!(out, msgs, "split-read reassembly must be lossless");
    println!("shape OK: frames survive 7-byte split reads losslessly");

    // Shape + cost: the parity sweep (in-process, TCP, UDS).
    let (table, outcomes) = loopback_parity(41);
    print!("{}", table.render());
    for o in &outcomes[1..] {
        assert!(
            (o.vs_inproc - 1.0).abs() < 0.05,
            "{}: {:.3}× in-process",
            o.transport,
            o.vs_inproc
        );
    }
    println!("shape OK: loopback transports within 5% of the in-process co-sim");

    // Frame codec, whole-buffer decode.
    bench.run("frame: encode+decode 1k attach frames", Some(1000.0), || {
        let mut bytes = 0usize;
        let mut dec = FrameDecoder::new();
        for i in 0..1000u64 {
            let frame = encode_frame(&attach_msg(i)).expect("encode");
            bytes += frame.len();
            dec.feed(&frame);
            let msg = dec.try_next().expect("decode").expect("complete frame");
            black_box(msg);
        }
        bytes as u64
    });

    // Frame codec under split reads (64-byte chunks — a pessimistic
    // socket read size).
    let mut big = Vec::new();
    for i in 0..1000u64 {
        big.extend_from_slice(&encode_frame(&attach_msg(i)).expect("encode"));
    }
    bench.run("frame: decode 1k frames from 64-byte reads", Some(1000.0), || {
        let mut dec = FrameDecoder::new();
        let mut n = 0u64;
        for chunk in big.chunks(64) {
            dec.feed(chunk);
            while let Some(m) = dec.try_next().expect("decode") {
                black_box(m);
                n += 1;
            }
        }
        assert_eq!(n, 1000);
        n
    });

    // One full remote co-simulation (what a transport sweep cell pays,
    // dominated by socket round trips per gossip epoch).
    let streams: Vec<StreamSpec> = (0..8)
        .map(|i| StreamSpec::new(&format!("cam{i}"), 10.0, 300).with_window(4))
        .collect();
    let pool = |n: usize| -> Vec<eva::device::DeviceInstance> {
        (0..n)
            .map(|i| {
                eva::device::DeviceInstance::with_rate(
                    eva::device::DeviceKind::Ncs2,
                    eva::device::DetectorModelId::Yolov3,
                    i,
                    2.5,
                )
            })
            .collect()
    };
    let scenario = eva::shard::ShardScenario::builder(vec![pool(4), pool(4)], streams)
        .admission(eva::fleet::AdmissionPolicy::admit_all())
        .gossip(10.0)
        .epochs(5)
        .seed(43)
        .build();
    bench.run("co-sim: 8 streams × 2 shards over loopback TCP", Some(8.0 * 300.0), || {
        let report = eva::shard::run_sharded_remote(&scenario, eva::shard::RemoteTransport::Tcp)
            .expect("remote co-sim");
        black_box(report.delivered_fps().to_bits())
    });
}
