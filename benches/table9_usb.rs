//! Regenerates Table IX (USB 2.0 vs 3.0) and checks the signature shape:
//! USB 3.0 scales linearly for both models; USB 2.0 costs ~0.3-0.5 FPS at
//! n = 1 and caps YOLOv3 (larger payload) near 8 FPS at n ≥ 5 while
//! SSD300 keeps scaling to 13+ at n = 7. Also prints the Table VIII link
//! projection extension.

use eva::device::link::LinkProfile;
use eva::device::DetectorModelId;
use eva::experiments::links;

fn main() {
    let (table, sweeps) = links::table9(19);
    print!("{}", table.render());

    let find = |m: DetectorModelId, l: &str| {
        sweeps
            .iter()
            .find(|s| s.model == m && s.link.name == l)
            .unwrap()
    };
    let yolo2 = find(DetectorModelId::Yolov3, "USB 2.0");
    let yolo3 = find(DetectorModelId::Yolov3, "USB 3.0");
    let ssd2 = find(DetectorModelId::Ssd300, "USB 2.0");

    // n = 1 rates (paper: 1.9 / 2.5 / 2.0).
    assert!((yolo2.by_n[0].1 - 1.9).abs() < 0.15, "{}", yolo2.by_n[0].1);
    assert!((yolo3.by_n[0].1 - 2.5).abs() < 0.15, "{}", yolo3.by_n[0].1);
    assert!((ssd2.by_n[0].1 - 2.0).abs() < 0.15, "{}", ssd2.by_n[0].1);
    // YOLO USB2 plateau at ~8 (paper: 8.1 / 8.0 / 8.1 for n = 5..7).
    for i in 4..7 {
        assert!((yolo2.by_n[i].1 - 8.0).abs() < 0.7, "n={} {}", i + 1, yolo2.by_n[i].1);
    }
    // SSD USB2 keeps growing to ~13 (paper 13.2).
    assert!((ssd2.by_n[6].1 - 13.4).abs() < 1.0, "{}", ssd2.by_n[6].1);
    // USB3 linear to 17+ (paper 17.3).
    assert!((yolo3.by_n[6].1 - 17.3).abs() < 0.8, "{}", yolo3.by_n[6].1);
    println!("shape OK: USB2 plateau for YOLO at ~8 FPS, SSD scales, USB3 linear");

    let (proj, _) = links::link_projection(20);
    print!("{}", proj.render());
}
