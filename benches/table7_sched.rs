//! Regenerates Table VII (RR vs FCFS on homogeneous and heterogeneous
//! fleets) and checks its three findings:
//!   1. NCS2-only: RR ≈ FCFS (both ≈ n·μ);
//!   2. fast CPU + sticks: FCFS ≫ RR (29 vs 20.1 at n = 7);
//!   3. slow CPU + sticks: RR collapses (3.4 at n = 7) while FCFS gets
//!      sticks + 0.4 (17.9).
//! Also prints the all-scheduler ablation (WRR + proportional).

use eva::coordinator::SchedulerKind;
use eva::experiments::sched::{self, FleetFamily};

fn main() {
    let (table, sweeps) = sched::table7(17);
    print!("{}", table.render());

    let get = |k: SchedulerKind, f: FleetFamily, n: usize| -> f64 {
        sweeps
            .iter()
            .find(|s| s.scheduler == k && s.family == f)
            .and_then(|s| s.by_n[n].1)
            .unwrap_or(f64::NAN)
    };
    use FleetFamily::*;
    use SchedulerKind::*;

    // (1) homogeneous: similar (RR's barrier pays max-of-n service-time
    // jitter per round, a few percent behind work-conserving FCFS).
    for n in [1usize, 4, 7] {
        let rr = get(RoundRobin, Ncs2Only, n);
        let fc = get(Fcfs, Ncs2Only, n);
        assert!((rr - fc).abs() / fc < 0.08, "n={n}: rr {rr} fcfs {fc}");
    }
    // (2) fast CPU: FCFS ≈ 13.5 + 2.5n; RR ≈ 2.5(n+1).
    let fc7 = get(Fcfs, FastCpuPlusNcs2, 7);
    let rr7 = get(RoundRobin, FastCpuPlusNcs2, 7);
    assert!((fc7 - 31.0).abs() < 2.5, "fcfs fast+7: {fc7} (paper 29.0)");
    assert!((rr7 - 19.8).abs() < 1.5, "rr fast+7: {rr7} (paper 20.1)");
    assert!(fc7 > rr7 + 6.0);
    // (3) slow CPU: RR collapses to ≈ (n+1)/2.5s-round pace.
    let rr_slow7 = get(RoundRobin, SlowCpuPlusNcs2, 7);
    let fc_slow7 = get(Fcfs, SlowCpuPlusNcs2, 7);
    assert!((rr_slow7 - 3.2).abs() < 0.5, "rr slow+7 {rr_slow7} (paper 3.4)");
    assert!((fc_slow7 - 17.9).abs() < 1.2, "fcfs slow+7 {fc_slow7} (paper 17.9)");
    println!("shape OK: RR==FCFS homogeneous; FCFS wins heterogeneous; RR straggler collapse");

    let (ablation, _) = sched::scheduler_ablation(18);
    print!("{}", ablation.render());
}
