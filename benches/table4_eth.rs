//! Regenerates Table IV (parallel detection on ETH-Sunnyday) and checks
//! the paper's shape: near-linear σ_P scaling (≈6.9× at n = 7), online
//! single-device mAP far below the zero-drop baseline, and recovery to
//! baseline within the §III-B band n ∈ [4, 6].

use eva::experiments::parallel;
use eva::util::benchkit::Bench;

fn main() {
    let (table, sweeps) = parallel::table4(7);
    print!("{}", table.render());

    // Shape assertions (paper values quoted in comments).
    for s in &sweeps {
        let mu = s.baseline.0;
        let speedup = s.by_n[6].1 / s.by_n[0].1; // paper: 6.96x / 6.92x
        assert!(
            speedup > 6.0 && speedup < 7.5,
            "{}: 7-stick speedup {speedup:.2}",
            s.model.label()
        );
        // Linear region: each extra stick adds ≈ μ.
        for (n, fps, _) in &s.by_n {
            let ideal = mu * *n as f64;
            assert!(
                (fps - ideal).abs() / ideal < 0.1,
                "{} n={n}: σ_P {fps:.1} vs ideal {ideal:.1}",
                s.model.label()
            );
        }
        // Dropping hurts; parallelism recovers (paper: 66.1 -> 86.9).
        assert!(s.single_map < s.baseline.1 - 0.05);
        let recovered = s.by_n[5].2; // n = 6
        assert!(
            (recovered - s.baseline.1).abs() < 0.06,
            "{}: n=6 mAP {recovered:.3} vs baseline {:.3}",
            s.model.label(),
            s.baseline.1
        );
    }
    println!("shape OK: linear scaling, ~6.9x at n=7, mAP recovery by n=6");

    // Timing: how fast the whole table regenerates (DES speed).
    let mut b = Bench::standard();
    b.run("table4: full sweep (28 runs)", Some(28.0), || {
        let (_, s) = parallel::table4(7);
        s.len()
    });
}
