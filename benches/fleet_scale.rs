//! Fleet scaling bench: aggregate detection FPS vs pool size at a fixed
//! stream count (8 streams), plus the admission-enforced sweep.
//!
//! Asserts the work-conserving shape: with admission off and windows
//! deep enough to keep the pool fed, aggregate σ tracks Σμᵢ (within
//! tolerance) and grows monotonically with the pool.

use eva::experiments::fleet::{saturation_sweep, scaling};
use eva::util::benchkit::Bench;

fn main() {
    let mut bench = Bench::new(1, 3);

    let (table, points) = saturation_sweep(29);
    print!("{}", table.render());
    for p in &points {
        let ratio = p.aggregate_fps / p.ideal_rate;
        assert!(
            (ratio - 1.0).abs() < 0.12,
            "m={}: aggregate σ {:.2} vs Σμ {:.2} (ratio {ratio:.3})",
            p.devices,
            p.aggregate_fps,
            p.ideal_rate
        );
    }
    for w in points.windows(2) {
        assert!(
            w[1].aggregate_fps > w[0].aggregate_fps,
            "σ must grow with the pool: {:?} -> {:?}",
            w[0].aggregate_fps,
            w[1].aggregate_fps
        );
    }
    println!("shape OK: aggregate σ ≈ Σμ at every pool size (work-conserving)");

    let (admission_table, admission_points) = scaling(31);
    print!("{}", admission_table.render());
    let last = admission_points[admission_points.len() - 1];
    assert_eq!(last.rejected, 0, "largest pool must admit everyone");
    println!("shape OK: admission relaxes from reject/degrade to full admit as the pool grows");

    // Wall-clock cost of one 8-stream virtual-time run (the thing CI and
    // sweeps pay per cell).
    bench.run("fleet sim: 8 streams × 4 devices (300 frames)", Some(8.0 * 300.0), || {
        saturation_sweep_cell()
    });
}

fn saturation_sweep_cell() -> u64 {
    use eva::device::{DetectorModelId, DeviceInstance, DeviceKind};
    use eva::fleet::{run_fleet, AdmissionPolicy, Scenario, StreamSpec};
    let devices: Vec<DeviceInstance> = (0..4)
        .map(|i| DeviceInstance::with_rate(DeviceKind::Ncs2, DetectorModelId::Yolov3, i, 2.5))
        .collect();
    let streams: Vec<StreamSpec> = (0..8)
        .map(|i| StreamSpec::new(&format!("s{i}"), 10.0, 300).with_window(16))
        .collect();
    let scenario = Scenario::new(devices, streams)
        .with_admission(AdmissionPolicy::admit_all())
        .with_seed(33);
    run_fleet(&scenario).total_processed()
}
