//! Autoscale bench: asserts the closed-loop shapes (quality ordering at
//! 2× overload, band convergence, elastic diurnal tracking) and times
//! one full closed-loop virtual-time run — the controller overhead CI
//! pays per sweep cell.

use eva::autoscale::{run_autoscale_sim, AutoscaleConfig, ModelLadder};
use eva::experiments::autoscale::{device_failure, diurnal, step_load};
use eva::experiments::fleet::pool_of;
use eva::fleet::{Scenario, StreamSpec};
use eva::util::benchkit::Bench;

fn main() {
    let mut bench = Bench::new(1, 3);

    // Acceptance shape: ladder+autoscale > ladder-only > stride-only on
    // delivered mAP at 2× overload, p99 bounded, fast rung recovery.
    let (table, outcomes) = step_load(29);
    print!("{}", table.render());
    let (stride, ladder_only, auto) = (&outcomes[0], &outcomes[1], &outcomes[2]);
    assert!(
        auto.overload_map > stride.overload_map + 0.15,
        "autoscale {:.3} must clearly beat stride-only {:.3}",
        auto.overload_map,
        stride.overload_map
    );
    assert!(
        ladder_only.overload_map > stride.overload_map + 0.10,
        "ladder admission {:.3} must beat stride-only {:.3}",
        ladder_only.overload_map,
        stride.overload_map
    );
    assert!(
        auto.overload_p99 < 1.5,
        "closed-loop p99 {:.2}s must hold the bound",
        auto.overload_p99
    );
    assert!(
        auto.recovery_seconds <= 5.0,
        "full quality must return within one cooldown, took {:.1}s",
        auto.recovery_seconds
    );
    println!("shape OK: ladder+autoscale > ladder-only > stride-only on delivered mAP\n");

    let (table, points, _) = diurnal(31);
    print!("{}", table.render());
    assert!(points[1].devices > points[0].devices && points[2].devices > points[1].devices);
    assert!(points[3].devices < points[2].devices);
    println!("shape OK: device count tracks the diurnal ramp both ways\n");

    let (table, outcomes) = device_failure(33);
    print!("{}", table.render());
    assert!(outcomes[1].recovery_seconds.is_finite());
    assert!(outcomes[1].post_failure_map > outcomes[0].post_failure_map);
    println!("shape OK: controller recovers failed capacity\n");

    // Wall-clock cost of one closed-loop run (8 streams, controller
    // ticking at 1 Hz of virtual time).
    bench.run(
        "autoscale sim: 2x step, ladder + device control",
        Some(3.0 * 400.0 + 5.0 * 150.0),
        closed_loop_cell,
    );
}

fn closed_loop_cell() -> u64 {
    let ladder = ModelLadder::from_profiles("eth_sunnyday");
    let cfg = AutoscaleConfig {
        max_devices: 12,
        ..AutoscaleConfig::default()
    }
    .with_ladder(ladder);
    let streams: Vec<StreamSpec> = (0..8)
        .map(|i| StreamSpec::new(&format!("s{i}"), 2.5, 200).with_window(4))
        .collect();
    let scenario = Scenario::new(pool_of(4, 2.5), streams)
        .with_admission(cfg.admission())
        .with_seed(35);
    run_autoscale_sim(&scenario, &cfg).report.total_processed()
}
