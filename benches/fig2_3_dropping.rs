//! Regenerates the Figure 2/3 study: zero-drop vs online-with-dropping on
//! ETH-Sunnyday with a single NCS2-class YOLOv3, including the per-frame
//! staleness/alignment of frames 64–67, and checks §II-B's numbers: the
//! online run drops ≈5 frames per processed frame and loses double-digit
//! mAP (paper: 86.9 % -> 66.1 %).

use eva::experiments::dropping;

fn main() {
    let (table, study) = dropping::fig2_3(29);
    print!("{}", table.render());

    // Zero-drop baseline near the paper's 86.9%.
    assert!(
        (study.map_zero_drop - 0.869).abs() < 0.08,
        "zero-drop {:.3}",
        study.map_zero_drop
    );
    // Dropping costs >= 10 mAP points (paper: ~21).
    let delta = study.map_zero_drop - study.map_online_single;
    assert!(delta > 0.10, "mAP delta {delta:.3}");
    // Drop rate ≈ (λ-μ)/λ = (14-2.5)/14 ≈ 0.82.
    assert!(
        (study.online_drop_rate - 0.82).abs() < 0.06,
        "drop rate {:.3}",
        study.online_drop_rate
    );
    // Frames 64..67: mostly stale and increasingly misaligned.
    let stale = study
        .focus_frames
        .iter()
        .filter(|(_, s, _)| s.is_some())
        .count();
    assert!(stale >= 3, "{stale}/4 stale");
    println!("shape OK: ~82% drops, double-digit mAP loss, stale frames misaligned");
}
