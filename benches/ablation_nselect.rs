//! Ablation: the §III-B n-selection rule. Sweeps n across and beyond the
//! recommended band on both videos and verifies the band's defining
//! properties: below ⌈10/μ⌉ the output misses the 10 FPS perception
//! floor; at ⌈λ/μ⌉ dropping (nearly) vanishes; beyond it extra devices
//! only add idle capacity (diminishing mAP returns per stick).

use eva::coordinator::{nselect, SchedulerKind};
use eva::device::link::LinkProfile;
use eva::device::{DetectorModelId, Fleet};
use eva::experiments::common::{online_map, saturated_fps};
use eva::util::table::{f, pct, Table};
use eva::video::{generate, presets};

fn main() {
    let spec = presets::eth_sunnyday(31);
    let clip = generate(&spec, None);
    let model = DetectorModelId::Yolov3;
    let mu = 2.5;
    let band = nselect::recommended_range(spec.fps, mu);
    println!("λ = {}, μ = {mu} -> band [{}, {}]\n", spec.fps, band.lo, band.hi);
    assert_eq!((band.lo, band.hi), (4, 6)); // paper §III-B

    let mut t = Table::new(
        "n-selection ablation (ETH-Sunnyday, YOLOv3)",
        &["n", "in band", "σ_P", "drop %", "mAP %", "idle capacity (FPS)"],
    );
    let mut results = Vec::new();
    for n in 1..=8usize {
        let fleet = Fleet::ncs2_sticks(n, model, LinkProfile::usb3());
        let cap = saturated_fps(&clip, &fleet, SchedulerKind::Fcfs, 100 + n as u64);
        let (map, drop) = online_map(&clip, &fleet, SchedulerKind::Fcfs, 200 + n as u64);
        let idle = (cap - spec.fps).max(0.0);
        t.row(vec![
            format!("{n}"),
            if band.contains(n) { "*".into() } else { "".into() },
            f(cap, 1),
            f(drop * 100.0, 1),
            pct(map),
            f(idle, 1),
        ]);
        results.push((n, cap, drop, map));
    }
    print!("{}", t.render());

    // Below the band: capacity under the 10 FPS perception floor.
    assert!(results[2].1 < nselect::PERCEPTION_FLOOR_FPS); // n = 3
    assert!(results[3].1 >= nselect::PERCEPTION_FLOOR_FPS - 0.5); // n = 4
    // At the conservative point: (almost) no drops.
    assert!(results[5].2 < 0.08, "n=6 drop {}", results[5].2); // n = 6
    // Beyond the band: mAP gain per stick collapses (< 1 point).
    let gain = results[7].3 - results[5].3;
    assert!(gain < 0.02, "n 6->8 mAP gain {gain:.3}");
    println!("shape OK: floor at ⌈10/μ⌉, drops vanish at ⌈λ/μ⌉, diminishing returns beyond");
}
