//! Micro-benchmarks of the PJRT runtime path: artifact compile time and
//! per-frame inference latency for both TinyDet variants (the real-work
//! numbers behind the edge_serving example), plus input marshalling.
//! Skips gracefully when artifacts are not built.

use std::path::PathBuf;

use eva::runtime::{load_manifest, ModelSpec};
use eva::util::benchkit::{black_box, Bench};
use eva::util::Rng;

fn main() {
    let dir = PathBuf::from("artifacts");
    let Ok(manifest) = load_manifest(&dir) else {
        println!("artifacts not built (run `make artifacts`); skipping runtime bench");
        return;
    };
    let mut b = Bench::standard();

    for name in ["essd", "eyolo"] {
        let Some(meta) = manifest.get(name) else { continue };
        let spec = ModelSpec::new(meta.clone());

        // Compile time (paid once per worker at startup).
        let mut built = None;
        b.run(&format!("pjrt: build+compile {name}"), None, || {
            built = Some(spec.build().unwrap());
        });
        let rt = built.unwrap();

        // Input marshalling.
        let rgb = vec![128u8; rt.meta().input_len()];
        b.run(&format!("pjrt: pixels->input {name}"), Some(1.0), || {
            rt.pixels_to_input(black_box(&rgb)).unwrap().len()
        });

        // Per-frame inference.
        let mut rng = Rng::new(1);
        let input: Vec<f32> = (0..rt.meta().input_len()).map(|_| rng.f32()).collect();
        let m = b.run(&format!("pjrt: infer {name} (1 frame)"), Some(1.0), || {
            rt.infer(black_box(&input)).unwrap().len()
        });
        let fps = 1.0 / m.mean.as_secs_f64();
        println!(
            "  -> {name}: {:.1} frames/s single-replica ({} MFLOPs/frame, {:.2} GFLOP/s)",
            fps,
            rt.meta().flops_per_frame / 1_000_000,
            rt.meta().flops_per_frame as f64 * fps / 1e9,
        );
    }
}
