//! Regenerates Table VI (power efficiency) and checks the paper's
//! ordering: NCS2 ≫ GPU > fast CPU > slow CPU in FPS/Watt, with the
//! paper's exact figure of merit for NCS2 (1.25).

use eva::experiments::energy;

fn main() {
    let (table, rows) = energy::table6();
    print!("{}", table.render());

    assert!((rows[0].fps_per_watt - 1.25).abs() < 1e-9); // NCS2, paper 1.25
    assert!((rows[3].fps_per_watt - 0.14).abs() < 0.01); // Titan X, paper 0.14
    assert!((rows[2].fps_per_watt - 0.11).abs() < 0.01); // fast CPU, paper 0.11
    assert!(rows[1].fps_per_watt < 0.04); // slow CPU, paper 0.03
    assert!(
        rows[0].fps_per_watt > rows[3].fps_per_watt
            && rows[3].fps_per_watt > rows[2].fps_per_watt
            && rows[2].fps_per_watt > rows[1].fps_per_watt
    );
    println!("shape OK: NCS2 most energy-efficient (1.25 FPS/W), GPU > CPU");

    let (tj, rows) = energy::joules_per_frame_comparison();
    print!("{}", tj.render());
    let stick = rows[0].1;
    assert!(rows.iter().skip(3).all(|(_, j)| stick < *j));
}
