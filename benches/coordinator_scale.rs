//! Coordinator-scale bench: per-epoch planning cost and control-plane
//! bytes at 100k+ simulated streams.
//!
//! Asserts the acceptance shapes on deterministic counters — grouped
//! planner reads grow sub-linearly in shard count while the flat
//! planner is exactly linear, and the binary digest codec holds a ≥3×
//! payload-size advantage over JSON at the 102 400-stream point — then
//! measures what one coordinator epoch costs in wall-clock.

use eva::experiments::scale::{coordinator_scale_at, scale_point};
use eva::util::benchkit::{black_box, Bench};

fn main() {
    let mut bench = Bench::standard();

    // The ladder: 4× shard steps at 25 streams per shard, topping out
    // at 4096 shards × 25 = 102 400 simulated streams.
    let (table, points) = coordinator_scale_at(&[256, 1024, 4096], 25, 47);
    print!("{}", table.render());

    for w in points.windows(2) {
        let (small, big) = (&w[0], &w[1]);
        assert_eq!(
            big.flat.reads(),
            4 * small.flat.reads(),
            "flat planning must be exactly linear in shard count"
        );
        let growth = big.grouped.reads() as f64 / small.grouped.reads() as f64;
        assert!(
            growth < 2.5,
            "grouped reads grew {growth:.2}x on a 4x fleet ({} -> {} shards)",
            small.shards,
            big.shards,
        );
    }
    let top = points.last().expect("ladder has points");
    assert!(
        top.streams >= 100_000,
        "top rung must cover 100k+ streams, got {}",
        top.streams
    );
    assert!(
        top.grouped.reads() < top.flat.reads() / 4,
        "grouped must read far fewer digests than flat at scale: {} vs {}",
        top.grouped.reads(),
        top.flat.reads(),
    );
    println!(
        "shape OK: grouped planning is sub-linear (top rung reads {} of {} flat at {} streams)",
        top.grouped.reads(),
        top.flat.reads(),
        top.streams,
    );

    assert!(
        top.json_digest_bytes >= 3 * top.binary_digest_bytes,
        "binary digests must be >=3x smaller than JSON at scale: {} vs {}",
        top.binary_digest_bytes,
        top.json_digest_bytes,
    );
    assert!(
        top.delta_ratio() >= 3.0,
        "delta stream must be >=3x smaller than snapshots: {} vs {}",
        top.delta_bytes,
        top.snapshot_bytes,
    );
    println!(
        "shape OK: binary digests {:.2}x smaller than JSON, deltas {:.2}x smaller than snapshots",
        top.codec_ratio(),
        top.delta_ratio(),
    );

    // Wall-clock corroboration for the counters above: one coordinator
    // epoch's worth of work (flat + grouped plan, digest + delta
    // encoding) at two fleet sizes.
    bench.run(
        "scale: coordinator epoch at 1024 shards (25.6k streams)",
        Some(1024.0 * 25.0),
        || {
            let p = scale_point(1024, 25, 47);
            black_box(p.grouped.reads() as u64)
        },
    );
    bench.run(
        "scale: coordinator epoch at 4096 shards (102.4k streams)",
        Some(4096.0 * 25.0),
        || {
            let p = scale_point(4096, 25, 47);
            black_box(p.grouped.reads() as u64)
        },
    );
}
