//! Regenerates Figure 5 (FPS + mAP trend vs #NCS2 on ADL-Rundle-6) as a
//! CSV series and checks the two visual features of the figure: straight
//! FPS lines and an mAP curve that rises then flattens.

use eva::experiments::parallel;

fn main() {
    let (table, sweeps) = parallel::fig5(13);
    print!("{}", table.render());
    println!("-- CSV for plotting --");
    print!("{}", table.to_csv());

    for s in &sweeps {
        // FPS series is straight: successive increments within 20% of μ.
        let mu = s.baseline.0;
        for w in s.by_n.windows(2) {
            let inc = w[1].1 - w[0].1;
            assert!(
                (inc - mu).abs() < 0.35 * mu,
                "{}: non-linear step {inc:.2} (μ = {mu})",
                s.model.label()
            );
        }
        // mAP rises from n=1 to the band, then flattens (paper: YOLOv3
        // stabilises at 62.7% for n >= 4).
        let early = s.by_n[0].2;
        let late_avg: f64 =
            s.by_n[4..].iter().map(|x| x.2).sum::<f64>() / (s.by_n.len() - 4) as f64;
        assert!(
            late_avg > early - 0.02,
            "{}: late mAP {late_avg:.3} vs early {early:.3}",
            s.model.label()
        );
        let spread: f64 = s.by_n[4..]
            .iter()
            .map(|x| (x.2 - late_avg).abs())
            .fold(0.0, f64::max);
        assert!(spread < 0.08, "{}: plateau spread {spread:.3}", s.model.label());
    }
    println!("shape OK: straight FPS lines; mAP rises then plateaus");
}
