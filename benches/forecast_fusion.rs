//! Forecast bench: the fused-control acceptance shapes, then what the
//! forecast layer costs — the per-epoch observe→predict hot path and a
//! full fused diurnal co-simulation.
//!
//! Asserts the shapes first (fused control attaches ahead of the ramp
//! where reactive control cannot; no extra migrations; delivered
//! quality at least matches), then measures.

use eva::autoscale::ladder::ModelLadder;
use eva::experiments::forecast::{
    attach_phases, delivered_quality, diurnal_scenario, forecast_tuning, DIURNAL_CAMS,
};
use eva::forecast::ShardForecast;
use eva::shard::run_sharded;
use eva::util::benchkit::{black_box, Bench};

fn main() {
    let mut bench = Bench::new(1, 3);

    // ---- Shapes: the diurnal acceptance sweep, in-process ------------
    let reactive = run_sharded(&diurnal_scenario(29, false));
    let fused = run_sharded(&diurnal_scenario(29, true));
    let (re_pre, re_post) = attach_phases(&reactive);
    let (fu_pre, fu_post) = attach_phases(&fused);
    assert!(re_post >= 1, "the ramp must force reactive repair attaches");
    assert!(
        fu_pre > re_pre,
        "fused control must attach ahead of the ramp: {fu_pre} vs {re_pre}"
    );
    assert!(
        fused.migrations <= reactive.migrations,
        "forecast fusion must not add migrations: {} vs {}",
        fused.migrations,
        reactive.migrations
    );
    let ladder = ModelLadder::from_profiles("eth_sunnyday");
    let q_fused = delivered_quality(&fused, &ladder);
    let q_reactive = delivered_quality(&reactive, &ladder);
    assert!(
        q_fused >= q_reactive - 1e-9,
        "fused delivered quality must at least match: {q_fused:.4} vs {q_reactive:.4}"
    );
    assert!(!fused.forecast_trace.is_empty() && reactive.forecast_trace.is_empty());
    println!(
        "shape OK: fused {fu_pre} pre-ramp / {fu_post} post-step attaches vs reactive {re_pre}/{re_post}, migrations {} vs {}",
        fused.migrations, reactive.migrations
    );

    // ---- Cost: the per-epoch forecaster hot path ---------------------
    // 6 streams × 1000 epochs of observe + aggregate predict — what one
    // shard pays per gossip epoch, times a long run.
    let cfg = forecast_tuning();
    bench.run("forecast: observe+predict, 6 streams × 1k epochs", Some(6_000.0), || {
        let mut fc = ShardForecast::new(cfg.clone());
        let mut acc = 0u64;
        for epoch in 0..1000usize {
            let mult = if epoch % 4 >= 2 { 2.0 } else { 1.0 };
            for s in 0..DIURNAL_CAMS {
                fc.observe(s, 1.4 * mult);
            }
            if let Some(rate) = fc.digest_rate() {
                acc = acc.wrapping_add(rate.to_bits());
            }
        }
        black_box(acc)
    });

    // ---- Cost: one fused diurnal co-simulation (a sweep cell) --------
    bench.run(
        "shard sim: fused diurnal co-sim (6 streams × 24 epochs)",
        Some(6.0 * 24.0),
        || {
            let report = run_sharded(&diurnal_scenario(37, true));
            black_box(((report.migrations as u64) << 32) | report.forecast_trace.len() as u64)
        },
    );
}
