//! Regenerates Table X (Python vs C++ implementation scaling) and checks
//! the motivating shape for this Rust coordinator: the GIL-bound
//! implementation plateaus near 9.8 FPS from n = 3 on, while the
//! compiled implementation scales ~7× to n = 7 (paper: 32.4), with
//! Python slightly ahead at n = 1 (4.8 vs 4.5).

use eva::experiments::lang;

fn main() {
    let (table, results) = lang::table10(23);
    print!("{}", table.render());

    let (_, py1, cpp1) = results[0];
    assert!((py1 - 4.8).abs() < 0.4, "py n=1 {py1} (paper 4.8)");
    assert!((cpp1 - 4.5).abs() < 0.4, "cpp n=1 {cpp1} (paper 4.5)");
    assert!(py1 > cpp1, "python wins at n=1 (C++ sync overhead)");

    for (n, py, _) in &results[2..] {
        assert!((py - 9.8).abs() < 0.8, "py n={n} {py} (paper plateau ~9.7)");
    }
    let (_, _, cpp7) = results[6];
    assert!(cpp7 > 28.0, "cpp n=7 {cpp7} (paper 32.4)");
    let scaling = cpp7 / cpp1;
    assert!(scaling > 6.0, "cpp scaling {scaling:.1}x (paper ~7x)");
    println!("shape OK: GIL plateau ≈9.8; compiled scales ~7x");
}
